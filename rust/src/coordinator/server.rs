//! Request router + phase-pipelined serving loop (std threads; tokio is
//! unavailable offline).
//!
//! The paper serves batch-size-1 prefill; the router's job is admission,
//! ordering and dispatch across worker engines. Policies: FCFS and
//! shortest-job-first (by context length — prefill cost is superlinear in
//! context, so SJF cuts mean TTFT under contention; the serving example
//! reports both).
//!
//! Every request moves through one unified lifecycle
//! ([`Lifecycle`]): `Queued -> Prefilling{chunk} -> Decoding{step} ->
//! Done`. Prefill optionally runs as *chunked* token slices
//! ([`ServerOptions::prefill_chunk`] / `FASTP_PREFILL_CHUNK`) so a long
//! prompt releases the engine at every slice boundary instead of
//! monopolizing it end-to-end; requests with `decode_tokens > 0`
//! continue past prefill as per-token decode steps co-scheduled between
//! prefill work — continuous batching. Decode steps are latency-critical
//! (a client is waiting on every token): they lead the ready ranking
//! under every policy, and co-resident decode lanes fuse through the
//! batch axis ([`crate::coordinator::engine::Engine::decode_step_group`]).
//!
//! Two scheduling modes share the same admission queue:
//!
//!  * **pipelined** (default): requests flow through the engine's
//!    resumable phases ([`crate::coordinator::engine::PrefillState`]).
//!    Workers pull one *phase* at a time from a Condvar-driven ready set,
//!    so the memory-bound index-generation phase of request *i+1* overlaps
//!    the compute-bound SAU/FFN phases of request *i*. All engines lease
//!    kernel threads from one shared [`PoolBudget`], sizing concurrent
//!    phase jobs to the machine budget instead of `n_workers x pool_size`;
//!    co-resident requests parked at the same phase fuse into one batched
//!    fan-out (QKV, IndexGen and the FFN tail on a shared layer, SAU at
//!    any layer), with the group width chosen adaptively from the
//!    simulator's priced marginal saving (see [`form_group`]).
//!  * **serial**: each worker runs a request end-to-end on a private
//!    static share of the thread budget — the PR-1 baseline the serving
//!    example compares against at equal total threads.
//!
//! Per-request outputs are bit-identical across modes, worker counts,
//! thread budgets and chunk sizes: phases step in order per request,
//! every kernel fan-out is thread-count-invariant, chunked slices are
//! closed under dense prefill, and decode steps are deterministic.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::config::{u280_fast_prefill, FpgaConfig, ModelConfig, BLOCK};
use crate::coordinator::engine::{
    phase_hint_slot, DecodeState, Engine, EngineConfig, Phase, PrefillArgs, PrefillRun,
    PrefillState,
};
use crate::coordinator::joblist::KvLayout;
use crate::coordinator::prefix::{PrefixConfig, PrefixStore};
use crate::model::ModelWeights;
use crate::sim::marginal_fuse_saving_us;
use crate::tensor::tile::KernelCtx;
use crate::util::pool::{AdaptiveHints, PoolBudget, WorkerPool, HINT_EWMA_ALPHA};
use crate::workload::prompts::{Priority, TraceRequest};

/// Queueing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    /// Shortest (context) job first.
    Sjf,
    /// Priority-class preemptive SJF: at every phase boundary the stage
    /// loop re-ranks runnable requests by (class, remaining-cost
    /// estimate) — a queued or parked `Interactive` request takes the
    /// next phase slot ahead of a parked `Batch` prefill (the parked
    /// state *yields*; its phase is never split, so outputs stay
    /// bit-identical). Parked *decode* steps rank as `Interactive`-class
    /// regardless of the request's admission class — each step is a
    /// token a client is actively waiting on — and their tiny remaining
    /// cost slots them between prefill chunks. Starvation-protected: a
    /// `Batch` request — parked *or* still queued — that has been passed
    /// over [`ServerOptions::max_yields`] times ages to the front of the
    /// rank order and drains.
    Preemptive,
}

/// Where a request is in its life — the serving layer's single source of
/// truth for "what happens to this request next": queued requests wait
/// for admission, prefilling requests step phases (per token-slice when
/// chunked), decoding requests step tokens, done requests have their
/// [`Completion`] on the results channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lifecycle {
    /// Waiting in the admission queue.
    Queued,
    /// Prefill in flight; `chunk` is the token-slice index currently
    /// being computed (always 0 for monolithic prefill).
    Prefilling { chunk: usize },
    /// Decode in flight; `step` is the number of tokens emitted so far.
    Decoding { step: usize },
    /// All tokens produced.
    Done,
}

/// Default cap on how many states a single fused phase step may take
/// (QKV/IndexGen/SAU/FFN-tail batching, and fused decode lanes). The
/// *actual* width is chosen per group at admission time: candidates join
/// while the simulator's priced marginal TTFT saving stays strictly
/// positive (see [`form_group`]), clamped by this cap — overridable per
/// server with [`ServerOptions::max_phase_batch`] or process-wide with
/// [`PHASE_BATCH_ENV`].
pub const DEFAULT_MAX_PHASE_BATCH: usize = 4;

/// Environment variable overriding the fused-phase width cap (validated;
/// see [`parse_phase_batch`]).
pub const PHASE_BATCH_ENV: &str = "FASTP_PHASE_BATCH";

static PHASE_BATCH_FROM_ENV: OnceLock<usize> = OnceLock::new();

/// Validate a `FASTP_PHASE_BATCH` value: a positive integer (a fused
/// group always contains at least its lead; 1 disables fusion).
pub fn parse_phase_batch(raw: &str) -> Result<usize, String> {
    let v: usize = raw
        .trim()
        .parse()
        .map_err(|_| format!("{PHASE_BATCH_ENV}={raw:?} is not an unsigned integer"))?;
    if v == 0 {
        return Err(format!("{PHASE_BATCH_ENV} must be > 0 (a group always has its lead)"));
    }
    Ok(v)
}

/// The single `FASTP_PHASE_BATCH` read point (resolved once per process
/// through [`crate::config::env::knob_or`] — invalid values warn and
/// fall back to [`DEFAULT_MAX_PHASE_BATCH`] rather than aborting).
pub fn env_phase_batch() -> usize {
    *PHASE_BATCH_FROM_ENV.get_or_init(|| {
        crate::config::env::knob_or(PHASE_BATCH_ENV, parse_phase_batch, DEFAULT_MAX_PHASE_BATCH)
    })
}

/// Environment variable setting the default prefill chunk size in
/// tokens (validated; see [`parse_prefill_chunk`]). 0 or unset keeps
/// prefill monolithic; [`ServerOptions::prefill_chunk`] overrides.
pub const PREFILL_CHUNK_ENV: &str = "FASTP_PREFILL_CHUNK";

static PREFILL_CHUNK_FROM_ENV: OnceLock<usize> = OnceLock::new();

/// Validate a `FASTP_PREFILL_CHUNK` value: a multiple of [`BLOCK`]
/// tokens (slices are block-aligned so per-BLOCK quant scales and the
/// schedule walk stay chunk-closed); 0 disables chunking.
pub fn parse_prefill_chunk(raw: &str) -> Result<usize, String> {
    let v: usize = raw
        .trim()
        .parse()
        .map_err(|_| format!("{PREFILL_CHUNK_ENV}={raw:?} is not an unsigned integer"))?;
    if v % BLOCK != 0 {
        return Err(format!(
            "{PREFILL_CHUNK_ENV} must be a multiple of {BLOCK} tokens (0 disables chunking)"
        ));
    }
    Ok(v)
}

/// The single `FASTP_PREFILL_CHUNK` read point (resolved once per
/// process through [`crate::config::env::knob_or`]; invalid values warn
/// and keep prefill monolithic).
pub fn env_prefill_chunk() -> usize {
    *PREFILL_CHUNK_FROM_ENV
        .get_or_init(|| crate::config::env::knob_or(PREFILL_CHUNK_ENV, parse_prefill_chunk, 0))
}

/// Environment variable setting the default replica count for
/// [`crate::coordinator::cluster::Cluster`] serving (validated; see
/// [`parse_replicas`]). Unset or 1 keeps serving single-replica;
/// [`ServerOptions::replicas`] overrides.
pub const REPLICAS_ENV: &str = "FASTP_REPLICAS";

static REPLICAS_FROM_ENV: OnceLock<usize> = OnceLock::new();

/// Validate a `FASTP_REPLICAS` value: a positive integer (a cluster
/// always has at least one replica).
pub fn parse_replicas(raw: &str) -> Result<usize, String> {
    let v: usize = raw
        .trim()
        .parse()
        .map_err(|_| format!("{REPLICAS_ENV}={raw:?} is not an unsigned integer"))?;
    if v == 0 {
        return Err(format!("{REPLICAS_ENV} must be > 0 (a cluster has at least one replica)"));
    }
    Ok(v)
}

/// The single `FASTP_REPLICAS` read point (resolved once per process
/// through [`crate::config::env::knob_or`]; invalid values warn and keep
/// serving single-replica).
pub fn env_replicas() -> usize {
    *REPLICAS_FROM_ENV.get_or_init(|| crate::config::env::knob_or(REPLICAS_ENV, parse_replicas, 1))
}

/// Admission threshold for growing a fused phase group (µs of priced
/// marginal saving per layer): a candidate joins only while the saving
/// strictly exceeds this. 0.0 = any strictly positive priced saving is
/// worth taking; operators bound width with the cap, not the floor.
const MARGINAL_SAVING_FLOOR_US: f64 = 0.0;

/// Default aging bound: a parked or queued `Batch` request is passed over
/// at most this many phase-boundary slots before it outranks everything
/// and drains.
pub const DEFAULT_MAX_YIELDS: usize = 256;

/// Server scheduling options. Construct via [`ServerOptions::new`] /
/// [`ServerOptions::serial`] for the common presets, or
/// [`ServerOptions::builder`] for validated field-by-field setup.
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Phase-worker (pipelined) or engine-worker (serial) thread count.
    pub n_workers: usize,
    pub policy: Policy,
    /// Phase-pipelined scheduling (default) vs the serial end-to-end
    /// baseline.
    pub pipelined: bool,
    /// Total kernel-thread budget shared by all workers. 0 => the engine
    /// config's `threads`, falling back to `FASTP_THREADS` / available
    /// parallelism.
    pub total_threads: usize,
    /// Max co-resident requests in the pipeline (0 => `n_workers + 1`,
    /// one extra so the next request's phase 1 can overlap the tail
    /// phases of the ones in flight). A request continuing into decode
    /// stays in flight until its last token. Serial mode ignores this:
    /// each worker carries exactly one request.
    pub max_inflight: usize,
    /// Fuse same-phase jobs of co-resident requests into one fan-out.
    pub batch_phases: bool,
    /// Cap on the fused-group width (states per fused phase step, decode
    /// lanes included). 0 => the `FASTP_PHASE_BATCH` env override,
    /// falling back to [`DEFAULT_MAX_PHASE_BATCH`]. The width actually
    /// used is adaptive — a prefill group grows only while the simulator
    /// prices a strictly positive marginal saving for the next lane;
    /// this is the clamp.
    pub max_phase_batch: usize,
    /// Aging bound for [`Policy::Preemptive`]: after being passed over
    /// this many phase-boundary slots, a parked or queued `Batch` request
    /// outranks everything and runs to completion (0 =>
    /// [`DEFAULT_MAX_YIELDS`]).
    pub max_yields: usize,
    /// Feed completed requests' measured per-phase job costs back into
    /// per-phase lease-want sizing (EWMA, [`AdaptiveHints`]) instead of
    /// the static IndexGen split. Pipelined mode only; cold-start (first
    /// observation pending) behavior is the static split either way, and
    /// hint sizing never changes outputs.
    pub adaptive_hints: bool,
    /// Attach a content-hashed cross-request prefix KV store
    /// ([`crate::coordinator::prefix`]), shared by every worker's engine:
    /// completed prefills publish their leading blocks, later requests
    /// with hash-matching prefixes resume at their first novel block.
    /// `None` (default) serves every request cold. Dense mode only —
    /// engines with sparse SIGU enabled ignore the store.
    pub prefix: Option<PrefixConfig>,
    /// Chunked prefill slice size in **tokens** (pipelined mode only).
    /// 0 => the `FASTP_PREFILL_CHUNK` env override, falling back to
    /// monolithic prefill. Must be a multiple of [`BLOCK`] (the builder
    /// validates; a raw field write is rounded down to whole blocks).
    /// Chunked slices release the engine at every slice boundary, so a
    /// long prompt no longer monopolizes a worker end-to-end — the
    /// scheduler can slot interactive admissions and decode steps
    /// between slices. Dense-only: engines with sparse SIGU fall back to
    /// monolithic prefill (sparse indices are not chunk-closed).
    pub prefill_chunk: usize,
    /// Replica count for [`crate::coordinator::cluster::Cluster`]
    /// serving: N independent servers (each its own worker pool share
    /// and prefix store) behind a router. 0 => the `FASTP_REPLICAS` env
    /// override, falling back to 1. A plain [`Server`] ignores this —
    /// the cluster is the multiplexer, and it launches each replica
    /// server with `replicas = 1`.
    pub replicas: usize,
}

impl ServerOptions {
    /// Pipelined defaults.
    pub fn new(n_workers: usize, policy: Policy) -> ServerOptions {
        ServerOptions {
            n_workers,
            policy,
            pipelined: true,
            total_threads: 0,
            max_inflight: 0,
            batch_phases: true,
            max_phase_batch: 0,
            max_yields: 0,
            adaptive_hints: true,
            prefix: None,
            prefill_chunk: 0,
            replicas: 0,
        }
    }

    /// The serial end-to-end baseline (static per-worker thread split,
    /// static lease hints — the PR-1/PR-3 behavior).
    pub fn serial(n_workers: usize, policy: Policy) -> ServerOptions {
        ServerOptions {
            pipelined: false,
            adaptive_hints: false,
            ..ServerOptions::new(n_workers, policy)
        }
    }

    /// Validated field-by-field construction; starts from
    /// [`ServerOptions::default`] (one pipelined FCFS worker).
    pub fn builder() -> ServerOptionsBuilder {
        ServerOptionsBuilder { opts: ServerOptions::default() }
    }
}

impl Default for ServerOptions {
    /// One pipelined FCFS worker — identical to
    /// `ServerOptions::new(1, Policy::Fcfs)`.
    fn default() -> ServerOptions {
        ServerOptions::new(1, Policy::Fcfs)
    }
}

/// Typed builder for [`ServerOptions`]: setters stay `Copy`-cheap and
/// defer all validation to [`ServerOptionsBuilder::build`], which
/// returns one actionable error instead of panicking mid-serve or
/// silently clamping. Presets remain available —
/// `ServerOptions::new`/`serial` are unchanged — the builder is for
/// callers composing several non-default knobs (the serving example and
/// CI legs).
#[derive(Clone, Copy, Debug)]
pub struct ServerOptionsBuilder {
    opts: ServerOptions,
}

impl ServerOptionsBuilder {
    pub fn n_workers(mut self, n: usize) -> Self {
        self.opts.n_workers = n;
        self
    }

    pub fn policy(mut self, p: Policy) -> Self {
        self.opts.policy = p;
        self
    }

    /// `false` selects the serial end-to-end baseline (which also
    /// disables adaptive hints, as [`ServerOptions::serial`] does).
    pub fn pipelined(mut self, on: bool) -> Self {
        self.opts.pipelined = on;
        if !on {
            self.opts.adaptive_hints = false;
        }
        self
    }

    pub fn total_threads(mut self, n: usize) -> Self {
        self.opts.total_threads = n;
        self
    }

    pub fn max_inflight(mut self, n: usize) -> Self {
        self.opts.max_inflight = n;
        self
    }

    pub fn batch_phases(mut self, on: bool) -> Self {
        self.opts.batch_phases = on;
        self
    }

    pub fn max_phase_batch(mut self, n: usize) -> Self {
        self.opts.max_phase_batch = n;
        self
    }

    pub fn max_yields(mut self, n: usize) -> Self {
        self.opts.max_yields = n;
        self
    }

    pub fn adaptive_hints(mut self, on: bool) -> Self {
        self.opts.adaptive_hints = on;
        self
    }

    pub fn prefix(mut self, p: PrefixConfig) -> Self {
        self.opts.prefix = Some(p);
        self
    }

    /// Chunked prefill slice size in tokens (see
    /// [`ServerOptions::prefill_chunk`]); must be a multiple of
    /// [`BLOCK`], checked at [`ServerOptionsBuilder::build`].
    pub fn prefill_chunk(mut self, tokens: usize) -> Self {
        self.opts.prefill_chunk = tokens;
        self
    }

    /// Replica count for cluster serving (see
    /// [`ServerOptions::replicas`]); 0 defers to `FASTP_REPLICAS`.
    pub fn replicas(mut self, n: usize) -> Self {
        self.opts.replicas = n;
        self
    }

    /// Validate and produce the options. Errors name the offending
    /// field and its constraint.
    pub fn build(self) -> Result<ServerOptions, String> {
        let o = self.opts;
        if o.n_workers == 0 {
            return Err("n_workers must be >= 1".to_string());
        }
        if o.prefill_chunk % BLOCK != 0 {
            return Err(format!(
                "prefill_chunk must be a multiple of {BLOCK} tokens (0 = monolithic), got {}",
                o.prefill_chunk
            ));
        }
        if !o.pipelined && o.prefill_chunk > 0 {
            return Err(
                "prefill_chunk requires pipelined scheduling (the serial baseline runs \
                 monolithic prefills)"
                    .to_string(),
            );
        }
        Ok(o)
    }
}

/// A completed request with serving-side timing.
#[derive(Clone, Debug)]
pub struct Completion {
    pub request_id: u64,
    pub run: PrefillRun,
    /// Scheduling class the request was served under.
    pub priority: Priority,
    /// Queue wait (us) before the request was admitted into an engine.
    pub queue_us: f64,
    /// Time parked between phases waiting for a worker (us) — the
    /// pipeline-stall component of TTFT (0 in serial mode).
    pub pipeline_wait_us: f64,
    /// End-to-end latency including queueing (us). For decoding requests
    /// this covers generation too — `first_token_us` is the
    /// user-perceived TTFT.
    pub e2e_us: f64,
    /// Phase-boundary slots this request yielded to higher-ranked
    /// requests ([`Policy::Preemptive`] only; 0 elsewhere). For `Batch`
    /// requests the aging limit [`ServerOptions::max_yields`] bounds
    /// this; `Interactive` requests only yield to aged batches and are
    /// not aging-bounded themselves.
    pub preemptions: u64,
    /// Submission -> first token (us). 0 on prefill-only requests,
    /// where the first token *is* the end of the request (`e2e_us`).
    pub first_token_us: f64,
    /// Tokens generated by decode steps after prefill (empty =
    /// prefill-only request). Bit-identical to a solo
    /// [`crate::model::decode::Decoder::generate`] continuation of the
    /// same prefill.
    pub decode_tokens: Vec<u8>,
    /// Wall-clock per decode step (us); fused lanes charge the fused
    /// step's wall time to every lane, like the fused prefill phases.
    pub decode_step_us: Vec<f64>,
    /// Decode-side KV gather/append HBM traffic priced through the
    /// memory spine ([`crate::coordinator::walk::DecodeStepWalk`]).
    pub decode_hbm_read_bytes: u64,
    pub decode_hbm_write_bytes: u64,
}

impl Completion {
    /// This completion's latency decomposition for
    /// [`crate::metrics::ServeSummary`] aggregation. TPOT is the mean
    /// decode-step time; ITL p95 the 95th-percentile step time (equal to
    /// TPOT only when step times are flat).
    pub fn sample(&self) -> crate::metrics::ServeSample {
        crate::metrics::ServeSample {
            kernel_backend: self.run.metrics.kernel_backend,
            priority: self.priority,
            ttft_us: self.run.metrics.ttft_us,
            queue_us: self.queue_us,
            pipeline_wait_us: self.pipeline_wait_us,
            e2e_us: self.e2e_us,
            preemptions: self.preemptions,
            hbm_read_bytes: self.run.metrics.hbm_read_bytes as f64,
            cache_hit_rate: self.run.metrics.cache_hit_rate,
            prefix_tokens_skipped: self.run.metrics.prefix_tokens_skipped,
            sigu_hbm_read_bytes: self.run.metrics.sigu_hbm_read_bytes,
            sigu_hbm_saved_bytes: self.run.metrics.sigu_hbm_saved_bytes,
            sigu_fused_phases: self.run.metrics.sigu_fused_phases,
            sigu_fused_width_sum: self.run.metrics.sigu_fused_width_sum,
            first_token_us: self.first_token_us,
            decode_tokens: self.decode_tokens.len() as u64,
            tpot_us: crate::util::stats::mean(&self.decode_step_us),
            itl_p95_us: crate::util::stats::percentile(&self.decode_step_us, 95.0),
            decode_hbm_read_bytes: self.decode_hbm_read_bytes,
            decode_hbm_write_bytes: self.decode_hbm_write_bytes,
            // a bare server is replica 0; ClusterRun::samples re-stamps
            // from its placement log
            replica: 0,
        }
    }
}

/// Serving-side request bookkeeping that rides along the phase states.
#[derive(Clone, Copy, Debug)]
struct ReqMeta {
    /// Admission sequence number (tie-break: earlier admission first).
    seq: u64,
    priority: Priority,
    /// Phase-boundary slots this parked state has yielded to
    /// higher-ranked requests; drives aging and the preemption counter.
    yields: u64,
    submitted_at: Instant,
    queue_us: f64,
    /// When the state was last parked in the ready set.
    parked_at: Instant,
    pipeline_wait_us: f64,
    /// Decode steps this request continues into after prefill (from
    /// [`TraceRequest::decode_tokens`]; 0 = prefill-only).
    decode_tokens: usize,
    /// Submission -> first token, recorded when prefill finishes on a
    /// decoding request (0 until then, and forever on prefill-only
    /// requests — their first token coincides with `e2e_us`).
    first_token_us: f64,
}

/// One schedulable work unit of an in-flight request: its resumable
/// prefill state, or — once prefill finished on a decoding request —
/// its parked decode state (the finished [`PrefillRun`] rides along for
/// the final [`Completion`]). [`form_group`] never mixes the two kinds
/// in one fused step.
enum Unit {
    Prefill(PrefillState),
    Decode { state: DecodeState, run: PrefillRun },
}

impl Unit {
    fn request_id(&self) -> u64 {
        match self {
            Unit::Prefill(st) => st.request_id,
            Unit::Decode { state, .. } => state.request_id,
        }
    }

    /// Lifecycle stage of this parked unit.
    fn lifecycle(&self) -> Lifecycle {
        match self {
            Unit::Prefill(st) => Lifecycle::Prefilling { chunk: st.chunk_index() },
            Unit::Decode { state, .. } if state.done() => Lifecycle::Done,
            Unit::Decode { state, .. } => Lifecycle::Decoding { step: state.step_index() },
        }
    }

    /// Remaining-work estimate in the shared phase-step cost units
    /// (decode steps are phase-sized and tiny next to prefill — which is
    /// exactly why the preemptive rank slots them between prefill
    /// chunks).
    fn remaining_cost(&self) -> u64 {
        match self {
            Unit::Prefill(st) => st.remaining_cost(),
            Unit::Decode { state, .. } => state.remaining_cost(),
        }
    }

    /// Most-advanced-first ordering key for the non-preemptive policies:
    /// decode steps lead (their token is due *now*), then prefill by
    /// (chunk, layer, phase) so older requests drain and TTFT stays low.
    fn progress_key(&self) -> (usize, usize, u8) {
        match self {
            Unit::Prefill(st) => (st.chunk_index(), st.layer(), phase_rank(st.phase())),
            Unit::Decode { .. } => (usize::MAX, usize::MAX, u8::MAX),
        }
    }

    #[cfg(test)]
    fn prefill(&self) -> &PrefillState {
        match self {
            Unit::Prefill(st) => st,
            Unit::Decode { .. } => panic!("not a prefill unit"),
        }
    }

    #[cfg(test)]
    fn prefill_mut(&mut self) -> &mut PrefillState {
        match self {
            Unit::Prefill(st) => st,
            Unit::Decode { .. } => panic!("not a prefill unit"),
        }
    }
}

/// An in-flight request parked between phase steps.
struct Pending {
    unit: Unit,
    meta: ReqMeta,
}

/// A request waiting in the admission queue.
struct Queued {
    req: TraceRequest,
    at: Instant,
    /// Phase-boundary picks that went to other work while this request
    /// sat queued ([`Policy::Preemptive`] only) — the queue-level twin of
    /// [`ReqMeta::yields`]: a never-admitted `Batch` request ages to the
    /// front of the rank order after `max_yields` passes, so the
    /// starvation bound covers the queue, not just parked states.
    passes: u64,
}

/// The admission queue + pipeline ready set shared between router and
/// workers. All waits are Condvar wakeups — no sleep-polling.
struct Shared {
    queue: VecDeque<Queued>,
    ready: Vec<Pending>,
    closed: bool,
    /// A worker hit an engine error; everyone drains out.
    aborted: bool,
    /// Admitted but not yet completed requests (parked + being stepped).
    inflight: usize,
    next_seq: u64,
    policy: Policy,
    /// Model depth, for the queued-request remaining-cost estimate
    /// (`4 * n_layers * tokens` — same units as
    /// [`PrefillState::remaining_cost`]).
    n_layers: usize,
    /// Aging bound (see [`ServerOptions::max_yields`]; resolved, >= 1).
    max_yields: usize,
    /// Fused-group width cap (see [`ServerOptions::max_phase_batch`];
    /// resolved, >= 1).
    max_phase_batch: usize,
    /// Model geometry of every lane this server admits — the fused-group
    /// layout gate and the marginal-saving pricer read it.
    model: ModelConfig,
    /// Platform the admission-time marginal-saving pricer runs against.
    fpga: FpgaConfig,
}

struct Sched {
    shared: Mutex<Shared>,
    cond: Condvar,
}

/// Worker drop guard: a panic unwinding out of a phase step (outside the
/// scheduler lock) would otherwise leave `inflight` counted forever and
/// wedge the peers' Condvar exit condition — flag the abort so everyone
/// drains out and `drain()` surfaces the panic via `join`.
struct AbortOnPanic<'a>(&'a Sched);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let mut s =
                self.0.shared.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            s.aborted = true;
            drop(s);
            self.0.cond.notify_all();
        }
    }
}

/// One unit of worker work.
enum Work {
    /// Admit a queued request (build its `PrefillState`).
    Admit(TraceRequest, Instant),
    /// Step the next phase of these co-resident requests: all prefill or
    /// all decode, never mixed (len > 1 only when the group fuses — same
    /// phase, and same layer for QKV, for prefill; any co-parked lanes
    /// for decode).
    Phases(Vec<Pending>),
}

/// Multi-worker prefill server. Each worker owns an [`Engine`] (PJRT
/// clients are not shared across threads), but all engines share one
/// generated [`ModelWeights`] and — in pipelined mode — one kernel-thread
/// budget.
pub struct Server {
    sync: Arc<Sched>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    results_rx: Receiver<Completion>,
}

impl Server {
    /// Spawn `n_workers` engines over the same artifacts/config with the
    /// default (pipelined) scheduling options.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        n_workers: usize,
        policy: Policy,
    ) -> Result<Server> {
        Server::start_with(artifact_dir, cfg, ServerOptions::new(n_workers, policy))
    }

    /// Spawn the server with explicit scheduling options. The model is
    /// generated once and shared by every worker.
    pub fn start_with(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        opts: ServerOptions,
    ) -> Result<Server> {
        let weights = Arc::new(ModelWeights::generate(&cfg.model, cfg.weight_seed));
        Server::start_with_weights(artifact_dir, cfg, opts, weights)
    }

    /// Spawn the server over pre-generated shared weights — lets several
    /// servers (e.g. the example's serial-vs-pipelined comparison) reuse
    /// one model instance instead of regenerating it per server.
    pub fn start_with_weights(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        opts: ServerOptions,
        weights: Arc<ModelWeights>,
    ) -> Result<Server> {
        let n_workers = opts.n_workers.max(1);
        let total_threads = if opts.total_threads > 0 {
            opts.total_threads
        } else if cfg.threads > 0 {
            cfg.threads
        } else {
            WorkerPool::from_env().threads()
        };
        let max_inflight = if opts.max_inflight > 0 { opts.max_inflight } else { n_workers + 1 };
        let max_yields = if opts.max_yields > 0 { opts.max_yields } else { DEFAULT_MAX_YIELDS };
        let max_phase_batch =
            if opts.max_phase_batch > 0 { opts.max_phase_batch } else { env_phase_batch() };
        // resolved chunk size in whole blocks (the builder validates
        // multiples; a raw field write rounds down). Serial mode is the
        // monolithic baseline by definition.
        let chunk_blocks = if !opts.pipelined {
            0
        } else {
            let chunk =
                if opts.prefill_chunk > 0 { opts.prefill_chunk } else { env_prefill_chunk() };
            chunk / BLOCK
        };
        let budget = PoolBudget::new(total_threads);
        // one EWMA hint store shared by every worker's engine: completed
        // requests feed measured phase costs in, phase fan-outs size
        // their lease wants from it (static split until first feedback)
        let hints = (opts.pipelined && opts.adaptive_hints)
            .then(|| AdaptiveHints::new(HINT_EWMA_ALPHA));
        // one prefix KV store shared by every worker's engine, so a
        // prefill completed on worker A is reusable by worker B
        let prefix_store = opts.prefix.map(|p| {
            Arc::new(Mutex::new(PrefixStore::new(cfg.model.name, cfg.weight_seed, p)))
        });
        let sync = Arc::new(Sched {
            shared: Mutex::new(Shared {
                queue: VecDeque::new(),
                ready: Vec::new(),
                closed: false,
                aborted: false,
                inflight: 0,
                next_seq: 0,
                policy: opts.policy,
                n_layers: cfg.model.n_layers,
                max_yields,
                max_phase_batch,
                model: cfg.model.clone(),
                fpga: u280_fast_prefill(),
            }),
            cond: Condvar::new(),
        });
        let (tx, rx): (Sender<Completion>, Receiver<Completion>) = channel();
        let mut workers = Vec::new();
        for _ in 0..n_workers {
            let sync = Arc::clone(&sync);
            let tx = tx.clone();
            let dir = artifact_dir.clone();
            let cfg = cfg.clone();
            let weights = Arc::clone(&weights);
            let budget = Arc::clone(&budget);
            let hints = hints.clone();
            let prefix_store = prefix_store.clone();
            workers.push(std::thread::spawn(move || -> Result<()> {
                let _abort_guard = AbortOnPanic(&sync);
                let out = (|| {
                    let mut engine = Engine::with_weights(&dir, cfg, weights)?;
                    engine.hints = hints;
                    engine.prefix = prefix_store;
                    engine.ctx = if opts.pipelined {
                        // lease from the shared machine budget per phase job
                        KernelCtx::with_pool(WorkerPool::shared(total_threads, budget))
                    } else {
                        // the serial baseline: a static equal split of the
                        // same total budget
                        KernelCtx::with_pool(WorkerPool::with_threads(
                            (total_threads / n_workers).max(1),
                        ))
                    };
                    if opts.pipelined {
                        worker_pipelined(
                            &sync,
                            &mut engine,
                            &tx,
                            max_inflight,
                            opts.batch_phases,
                            chunk_blocks,
                        )
                    } else {
                        worker_serial(&sync, &mut engine, &tx)
                    }
                })();
                if out.is_err() {
                    // wake everyone so in-flight bookkeeping can't wedge
                    // the other workers on the condvar
                    let mut s = sync.shared.lock().unwrap();
                    s.aborted = true;
                    drop(s);
                    sync.cond.notify_all();
                }
                out
            }));
        }
        drop(tx);
        Ok(Server { sync, workers, results_rx: rx })
    }

    /// Enqueue a request (non-blocking).
    pub fn submit(&self, req: TraceRequest) {
        let mut s = self.sync.shared.lock().unwrap();
        s.queue.push_back(Queued { req, at: Instant::now(), passes: 0 });
        drop(s);
        self.sync.cond.notify_all();
    }

    /// Open-loop trace replay: submit each request at its
    /// `TraceRequest::arrival_us` offset from the call (sleeping on the
    /// caller thread between arrivals), regardless of completions — so
    /// bursts queue up exactly as the trace recorded them. Returns once
    /// the last request has been submitted; queue-wait measurement starts
    /// at each submission as usual. Closed-loop callers (submit
    /// everything up front) just call [`Server::submit`] in a loop.
    pub fn replay(&self, trace: &crate::workload::prompts::RequestTrace) {
        let t0 = Instant::now();
        let mut reqs = trace.requests.clone();
        reqs.sort_by_key(|r| r.arrival_us);
        for r in reqs {
            let target = std::time::Duration::from_micros(r.arrival_us);
            let elapsed = t0.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            self.submit(r);
        }
    }

    /// Snapshot the lifecycle stage of every queued or parked request,
    /// sorted by request id. Requests currently being stepped by a
    /// worker are absent until they park again; completed requests live
    /// on the results channel, not here.
    pub fn lifecycles(&self) -> Vec<(u64, Lifecycle)> {
        let s = self.sync.shared.lock().unwrap();
        lifecycle_snapshot(&s)
    }

    /// Close the queue and collect all completions.
    pub fn drain(self) -> Result<Vec<Completion>> {
        {
            let mut s = self.sync.shared.lock().unwrap();
            s.closed = true;
        }
        self.sync.cond.notify_all();
        let mut out = Vec::new();
        for c in self.results_rx.iter() {
            out.push(c);
        }
        for w in self.workers {
            w.join().expect("worker panicked")?;
        }
        out.sort_by_key(|c| c.request_id);
        Ok(out)
    }
}

fn lifecycle_snapshot(s: &Shared) -> Vec<(u64, Lifecycle)> {
    let mut out: Vec<(u64, Lifecycle)> =
        s.queue.iter().map(|q| (q.req.id, Lifecycle::Queued)).collect();
    out.extend(s.ready.iter().map(|p| (p.unit.request_id(), p.unit.lifecycle())));
    out.sort_by_key(|&(id, _)| id);
    out
}

/// Serial worker: admit one request, run the monolithic prefill (and its
/// decode continuation inline, when the request asks for tokens), repeat.
fn worker_serial(sync: &Sched, engine: &mut Engine, tx: &Sender<Completion>) -> Result<()> {
    loop {
        let item = {
            let mut s = sync.shared.lock().unwrap();
            loop {
                if s.aborted {
                    return Ok(());
                }
                if let Some(it) = next_item(&mut s) {
                    s.inflight += 1;
                    break Some(it);
                }
                if s.closed {
                    break None;
                }
                s = sync.cond.wait(s).unwrap();
            }
        };
        let Some((req, submitted_at)) = item else { return Ok(()) };
        let queue_us = submitted_at.elapsed().as_micros() as f64;
        let tokens = req.spec.generate();
        let (run, first_token_us, decode) = if req.decode_tokens > 0 {
            let mut st = engine.prefill_start_with(
                req.id,
                &tokens,
                PrefillArgs { chunk_blocks: 0, capture_decode: true },
            )?;
            let mut run = loop {
                if let Some(r) = engine.phase_step(&mut st)? {
                    break r;
                }
            };
            let first_token_us = submitted_at.elapsed().as_micros() as f64;
            let mut ds = engine.decode_start(req.id, &run, req.decode_tokens)?;
            run.decode_inputs = None; // the seed is consumed; drop the capture
            while !ds.done() {
                engine.decode_step(&mut ds)?;
            }
            (run, first_token_us, Some(ds))
        } else {
            (engine.prefill(req.id, &tokens)?, 0.0, None)
        };
        let e2e_us = submitted_at.elapsed().as_micros() as f64;
        let (decode_tokens, decode_step_us, d_read, d_write) = match decode {
            Some(ds) => (ds.tokens, ds.step_us, ds.hbm_read_bytes, ds.hbm_write_bytes),
            None => (Vec::new(), Vec::new(), 0, 0),
        };
        let _ = tx.send(Completion {
            request_id: req.id,
            run,
            priority: req.priority,
            queue_us,
            pipeline_wait_us: 0.0,
            e2e_us,
            preemptions: 0,
            first_token_us,
            decode_tokens,
            decode_step_us,
            decode_hbm_read_bytes: d_read,
            decode_hbm_write_bytes: d_write,
        });
        let mut s = sync.shared.lock().unwrap();
        s.inflight -= 1;
        drop(s);
        sync.cond.notify_all();
    }
}

/// Pipelined worker: pull one phase step, decode step, or admission at a
/// time.
fn worker_pipelined(
    sync: &Sched,
    engine: &mut Engine,
    tx: &Sender<Completion>,
    max_inflight: usize,
    batch_phases: bool,
    chunk_blocks: usize,
) -> Result<()> {
    loop {
        let work = {
            let mut s = sync.shared.lock().unwrap();
            loop {
                if s.aborted {
                    return Ok(());
                }
                if let Some(w) = pick_work(&mut s, max_inflight, batch_phases) {
                    break w;
                }
                if s.closed && s.queue.is_empty() && s.inflight == 0 {
                    return Ok(());
                }
                s = sync.cond.wait(s).unwrap();
            }
        };
        match work {
            Work::Admit(req, submitted_at) => {
                let queue_us = submitted_at.elapsed().as_micros() as f64;
                let tokens = req.spec.generate();
                let state = engine.prefill_start_with(
                    req.id,
                    &tokens,
                    PrefillArgs { chunk_blocks, capture_decode: req.decode_tokens > 0 },
                )?;
                let mut s = sync.shared.lock().unwrap();
                let seq = s.next_seq;
                s.next_seq += 1;
                s.ready.push(Pending {
                    unit: Unit::Prefill(state),
                    meta: ReqMeta {
                        seq,
                        priority: req.priority,
                        yields: 0,
                        submitted_at,
                        queue_us,
                        parked_at: Instant::now(),
                        pipeline_wait_us: 0.0,
                        decode_tokens: req.decode_tokens,
                        first_token_us: 0.0,
                    },
                });
                drop(s);
                sync.cond.notify_all();
            }
            Work::Phases(group) => {
                let decode_led = matches!(group[0].unit, Unit::Decode { .. });
                let (parked, finished) = if decode_led {
                    step_decode_group(engine, tx, group)?
                } else {
                    step_prefill_group(engine, tx, group)?
                };
                let mut s = sync.shared.lock().unwrap();
                s.inflight -= finished;
                s.ready.extend(parked);
                drop(s);
                sync.cond.notify_all();
            }
        }
    }
}

/// Step a (possibly fused) prefill group outside the scheduler lock.
/// Finished prefills either complete (prefill-only) or seed a parked
/// decode unit ([`Engine::decode_start`] — KV re-derivation is
/// prefill-scale work, which is why it runs here and not under the
/// lock). Returns the units to re-park and the completed-request count.
fn step_prefill_group(
    engine: &mut Engine,
    tx: &Sender<Completion>,
    group: Vec<Pending>,
) -> Result<(Vec<Pending>, usize)> {
    let now = Instant::now();
    let mut states = Vec::with_capacity(group.len());
    let mut metas = Vec::with_capacity(group.len());
    for p in group {
        let mut meta = p.meta;
        meta.pipeline_wait_us += now.duration_since(meta.parked_at).as_micros() as f64;
        match p.unit {
            Unit::Prefill(st) => states.push(st),
            Unit::Decode { .. } => unreachable!("form_group never mixes lifecycles"),
        }
        metas.push(meta);
    }
    let results = engine.phase_step_group(&mut states)?;
    let mut parked = Vec::new();
    let mut finished = 0usize;
    for ((state, mut meta), result) in states.into_iter().zip(metas).zip(results) {
        match result {
            Some(mut run) => {
                // feed measured per-phase job costs back into the shared
                // adaptive lease-want EWMA
                if let Some(h) = engine.hints.as_ref() {
                    let m = &run.metrics;
                    h.observe(phase_hint_slot(Phase::Qkv), m.qkv_job_us);
                    h.observe(phase_hint_slot(Phase::IndexGen), m.sigu_job_us);
                    h.observe(phase_hint_slot(Phase::Sau), m.sau_job_us);
                    h.observe(phase_hint_slot(Phase::FfnLogits), m.ffn_job_us);
                }
                if meta.decode_tokens > 0 {
                    let state =
                        engine.decode_start(run.metrics.request_id, &run, meta.decode_tokens)?;
                    run.decode_inputs = None; // the seed is consumed; drop the capture
                    meta.first_token_us = meta.submitted_at.elapsed().as_micros() as f64;
                    parked.push(Pending {
                        unit: Unit::Decode { state, run },
                        meta: ReqMeta { parked_at: Instant::now(), ..meta },
                    });
                } else {
                    finished += 1;
                    let _ = tx.send(Completion {
                        request_id: run.metrics.request_id,
                        run,
                        priority: meta.priority,
                        queue_us: meta.queue_us,
                        pipeline_wait_us: meta.pipeline_wait_us,
                        e2e_us: meta.submitted_at.elapsed().as_micros() as f64,
                        preemptions: meta.yields,
                        first_token_us: 0.0,
                        decode_tokens: Vec::new(),
                        decode_step_us: Vec::new(),
                        decode_hbm_read_bytes: 0,
                        decode_hbm_write_bytes: 0,
                    });
                }
            }
            None => parked.push(Pending {
                unit: Unit::Prefill(state),
                meta: ReqMeta { parked_at: Instant::now(), ..meta },
            }),
        }
    }
    Ok((parked, finished))
}

/// Step a (possibly fused) decode group: one token per lane, fused
/// through [`Engine::decode_step_group`]. Lanes that reach their last
/// token complete; the rest park again.
fn step_decode_group(
    engine: &mut Engine,
    tx: &Sender<Completion>,
    group: Vec<Pending>,
) -> Result<(Vec<Pending>, usize)> {
    let now = Instant::now();
    let mut lanes: Vec<(DecodeState, PrefillRun)> = Vec::with_capacity(group.len());
    let mut metas = Vec::with_capacity(group.len());
    for p in group {
        let mut meta = p.meta;
        meta.pipeline_wait_us += now.duration_since(meta.parked_at).as_micros() as f64;
        match p.unit {
            Unit::Decode { state, run } => lanes.push((state, run)),
            Unit::Prefill(_) => unreachable!("form_group never mixes lifecycles"),
        }
        metas.push(meta);
    }
    {
        let mut refs: Vec<&mut DecodeState> = lanes.iter_mut().map(|(st, _)| st).collect();
        engine.decode_step_group(&mut refs)?;
    }
    let mut parked = Vec::new();
    let mut finished = 0usize;
    for ((state, run), meta) in lanes.into_iter().zip(metas) {
        if state.done() {
            finished += 1;
            let _ = tx.send(Completion {
                request_id: state.request_id,
                run,
                priority: meta.priority,
                queue_us: meta.queue_us,
                pipeline_wait_us: meta.pipeline_wait_us,
                e2e_us: meta.submitted_at.elapsed().as_micros() as f64,
                preemptions: meta.yields,
                first_token_us: meta.first_token_us,
                decode_tokens: state.tokens,
                decode_step_us: state.step_us,
                decode_hbm_read_bytes: state.hbm_read_bytes,
                decode_hbm_write_bytes: state.hbm_write_bytes,
            });
        } else {
            parked.push(Pending {
                unit: Unit::Decode { state, run },
                meta: ReqMeta { parked_at: Instant::now(), ..meta },
            });
        }
    }
    Ok((parked, finished))
}

/// Pipeline scheduling: step parked states first (decode steps lead,
/// then the most-advanced prefill, so older requests drain and their
/// TTFT stays low), admitting a new request only when no state is ready
/// and the pipeline has room. Admission order follows the queueing
/// policy; everything after admission is phase-availability driven.
/// [`Policy::Preemptive`] replaces the ready-first rule with a rank
/// order over *all* runnable requests — see [`pick_work_preemptive`].
fn pick_work(s: &mut Shared, max_inflight: usize, batch_phases: bool) -> Option<Work> {
    if s.policy == Policy::Preemptive {
        return pick_work_preemptive(s, max_inflight, batch_phases);
    }
    if !s.ready.is_empty() {
        let best = s
            .ready
            .iter()
            .enumerate()
            .max_by_key(|(_, p)| (p.unit.progress_key(), std::cmp::Reverse(p.meta.seq)))
            .map(|(i, _)| i)
            .unwrap();
        let lead = s.ready.swap_remove(best);
        return Some(Work::Phases(form_group(s, lead, batch_phases)));
    }
    if s.inflight < max_inflight {
        if let Some((req, at)) = next_item(s) {
            s.inflight += 1;
            return Some(Work::Admit(req, at));
        }
    }
    None
}

/// Scheduling rank of a runnable request under [`Policy::Preemptive`]:
/// class first (aged batch < interactive < batch), then the remaining-cost
/// estimate (SJF over what is *left*, so advanced short requests drain
/// first), then admission order. Lower ranks run first.
type PreemptRank = (u8, u64, u64);

/// Class component of the preemptive rank. A `Batch` request that has
/// yielded `max_yields` phase slots ages to rank 0 — ahead of everything —
/// so a sustained `Interactive` stream can delay it by at most
/// `max_yields` phase boundaries (the starvation bound).
fn class_rank(priority: Priority, yields: u64, max_yields: usize) -> u8 {
    match priority {
        Priority::Batch if yields >= max_yields as u64 => 0,
        Priority::Interactive => 1,
        Priority::Batch => 2,
    }
}

/// Class of a parked unit: prefill ranks by its admission class; decode
/// steps rank `Interactive` regardless — every step is a token a client
/// is actively waiting on, and with their near-zero remaining cost this
/// is what slots decode between a long prompt's prefill chunks.
fn unit_class(p: &Pending, max_yields: usize) -> u8 {
    match &p.unit {
        Unit::Prefill(_) => class_rank(p.meta.priority, p.meta.yields, max_yields),
        Unit::Decode { .. } => class_rank(Priority::Interactive, p.meta.yields, max_yields),
    }
}

fn pending_rank(p: &Pending, max_yields: usize) -> PreemptRank {
    (unit_class(p, max_yields), p.unit.remaining_cost(), p.meta.seq)
}

/// Rank of a queued (not yet admitted) request: nothing has run, so the
/// remaining cost is the full `4 * n_layers * tokens` — the same units as
/// [`PrefillState::remaining_cost`], making queued and parked work
/// directly comparable. Queue passes feed the same aging bound parked
/// yields do, so a never-admitted `Batch` request cannot starve under a
/// sustained `Interactive` stream.
fn queue_rank(q: &Queued, n_layers: usize, max_yields: usize) -> (u8, u64) {
    (
        class_rank(q.req.priority, q.passes, max_yields),
        4 * n_layers as u64 * q.req.spec.tokens as u64,
    )
}

/// Preemptive stage loop: at every phase boundary, re-rank all runnable
/// requests — parked states and queued arrivals — by (class,
/// remaining-cost, admission order). A queued request that strictly
/// outranks every parked state is admitted ahead of them (the parked
/// states *yield* the slot: that is the preemption, counted per yielding
/// request); otherwise the best-ranked parked state steps. Preemption
/// only reorders which unit advances next — a phase or decode step is
/// never split and states are never evicted — so per-request outputs
/// stay bit-identical to solo runs. Admission still respects
/// `max_inflight`.
fn pick_work_preemptive(s: &mut Shared, max_inflight: usize, batch_phases: bool) -> Option<Work> {
    let ready_best = s
        .ready
        .iter()
        .enumerate()
        .min_by_key(|(_, p)| pending_rank(p, s.max_yields))
        .map(|(i, p)| (pending_rank(p, s.max_yields), i));
    let queue_best = s
        .queue
        .iter()
        .enumerate()
        .min_by_key(|(_, q)| queue_rank(q, s.n_layers, s.max_yields))
        .map(|(i, q)| (queue_rank(q, s.n_layers, s.max_yields), i));

    if let Some(((q_class, q_cost), qi)) = queue_best {
        let jumps = match ready_best {
            // ready wins (class, cost) ties: advanced work drains first
            Some(((r_class, r_cost, _), _)) => (q_class, q_cost) < (r_class, r_cost),
            None => true,
        };
        if jumps && s.inflight < max_inflight {
            // every parked lower-class state just yielded its slot to a
            // newly admitted request — the preemption event
            charge_yields(s, q_class, u64::MAX);
            let q = s.queue.remove(qi).expect("queue_best index");
            s.inflight += 1;
            charge_queue_passes(s, q_class);
            return Some(Work::Admit(q.req, q.at));
        }
    }
    if let Some((_, i)) = ready_best {
        let lead = s.ready.swap_remove(i);
        let lead_class = unit_class(&lead, s.max_yields);
        let lead_seq = lead.meta.seq;
        let group = form_group(s, lead, batch_phases);
        // older lower-class states passed over at this phase boundary
        // yielded their slot (fused group members advanced, so only the
        // states still parked are charged)
        charge_yields(s, lead_class, lead_seq);
        charge_queue_passes(s, lead_class);
        return Some(Work::Phases(group));
    }
    None
}

/// Charge one yield to every parked state that is older than the winner
/// (`seq < winner_seq`) and of a strictly worse class — the states a
/// preemptive pick just jumped. Yields feed the per-request preemption
/// counter and the aging bound.
fn charge_yields(s: &mut Shared, winner_class: u8, winner_seq: u64) {
    let max_yields = s.max_yields;
    for i in 0..s.ready.len() {
        if s.ready[i].meta.seq < winner_seq
            && unit_class(&s.ready[i], max_yields) > winner_class
        {
            s.ready[i].meta.yields += 1;
        }
    }
}

/// Charge one pass to every *queued* request of a strictly worse class
/// than this pick's winner — the queue-level twin of [`charge_yields`].
/// Without it a `Batch` request that never wins admission accrues no
/// aging credit and can starve behind a sustained `Interactive` stream
/// even though parked batches are aging-protected.
fn charge_queue_passes(s: &mut Shared, winner_class: u8) {
    let max_yields = s.max_yields;
    for q in s.queue.iter_mut() {
        if class_rank(q.req.priority, q.passes, max_yields) > winner_class {
            q.passes += 1;
        }
    }
}

/// Grow the lead's step into a fused group. Lifecycles never mix: a
/// decode lead collects other parked decode lanes (no pricer — a decode
/// step is matvec/memory-bound, so sharing the weight stream across the
/// batch axis always saves; the width cap is the clamp), gated on a
/// compatible [`KvLayout`]. A prefill lead fuses same-phase parked
/// states: SAU at any layer, the K/weight-streaming phases (QKV,
/// IndexGen, FFN tail) only on a shared layer; IndexGen additionally
/// requires the kv-head layout gate. Prefill width is adaptive — a
/// candidate joins only while the simulator's priced marginal TTFT
/// saving ([`marginal_fuse_saving_us`]) strictly exceeds the floor,
/// clamped by the resolved [`ServerOptions::max_phase_batch`]. Chunked
/// prefill slices solo-step (slices change the priced geometry, and the
/// engine's batch phases run full-context lanes only). Grouping is
/// optimistic — the engine's batch phases re-check fusability and fall
/// back to per-state stepping, so correctness never depends on this
/// gate.
fn form_group(s: &mut Shared, lead: Pending, batch_phases: bool) -> Vec<Pending> {
    enum LeadKind {
        Decode,
        Prefill { phase: Phase, layer: usize, chunked: bool },
    }
    let kind = match &lead.unit {
        Unit::Decode { .. } => LeadKind::Decode,
        Unit::Prefill(st) => {
            LeadKind::Prefill { phase: st.phase(), layer: st.layer(), chunked: st.chunked() }
        }
    };
    let mut group = vec![lead];
    if !batch_phases {
        return group;
    }
    // every lane this server admits runs the one configured model, so
    // layouts always match today; the gate keeps the fusion contract
    // explicit (and checked) for a future multi-model router
    let lead_layout = KvLayout::of(&s.model);
    match kind {
        LeadKind::Decode => {
            let mut i = 0;
            while i < s.ready.len() && group.len() < s.max_phase_batch {
                let fusable = matches!(s.ready[i].unit, Unit::Decode { .. })
                    && KvLayout::of(&s.model).compatible(&lead_layout);
                if fusable {
                    group.push(s.ready.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        LeadKind::Prefill { chunked: true, .. } => {}
        LeadKind::Prefill { phase, layer, chunked: false } => {
            if matches!(phase, Phase::Qkv | Phase::IndexGen | Phase::Sau | Phase::FfnLogits) {
                let mut i = 0;
                while i < s.ready.len() && group.len() < s.max_phase_batch {
                    let Unit::Prefill(cand) = &s.ready[i].unit else {
                        i += 1;
                        continue;
                    };
                    let fusable = !cand.chunked()
                        && cand.phase() == phase
                        && (phase == Phase::Sau || cand.layer() == layer)
                        && (phase != Phase::IndexGen
                            || KvLayout::of(&s.model).compatible(&lead_layout));
                    let group_blocks: Vec<usize> = group
                        .iter()
                        .map(|g| match &g.unit {
                            Unit::Prefill(st) => st.context_tokens() / BLOCK,
                            Unit::Decode { .. } => {
                                unreachable!("prefill-led groups hold prefill lanes")
                            }
                        })
                        .collect();
                    let cand_blocks = cand.context_tokens() / BLOCK;
                    let saving_us = marginal_fuse_saving_us(
                        &s.fpga,
                        &s.model,
                        phase,
                        &group_blocks,
                        cand_blocks,
                    );
                    if fusable && saving_us > MARGINAL_SAVING_FLOOR_US {
                        group.push(s.ready.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }
    group
}

fn phase_rank(p: Phase) -> u8 {
    match p {
        Phase::Qkv => 0,
        Phase::IndexGen => 1,
        Phase::Sau => 2,
        Phase::FfnLogits => 3,
        Phase::Done => 4,
    }
}

fn next_item(s: &mut Shared) -> Option<(TraceRequest, Instant)> {
    if s.queue.is_empty() {
        return None;
    }
    let idx = match s.policy {
        Policy::Fcfs => 0,
        Policy::Sjf => s
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| q.req.spec.tokens)
            .map(|(i, _)| i)
            .unwrap_or(0),
        // class first (via the same class_rank the phase-boundary
        // ranking uses — one source of truth, queue passes included),
        // then SJF: what the serial baseline and the pipeline's
        // no-contention admission see of the preemptive rank
        Policy::Preemptive => s
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| {
                (class_rank(q.req.priority, q.passes, s.max_yields), q.req.spec.tokens)
            })
            .map(|(i, _)| i)
            .unwrap_or(0),
    };
    s.queue.remove(idx).map(|q| (q.req, q.at))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::prompts::{PromptKind, PromptSpec};

    fn req(id: u64, tokens: usize) -> TraceRequest {
        req_class(id, tokens, Priority::Interactive)
    }

    fn req_class(id: u64, tokens: usize, priority: Priority) -> TraceRequest {
        TraceRequest {
            id,
            spec: PromptSpec { kind: PromptKind::Random, tokens, seed: id },
            arrival_us: 0,
            priority,
            decode_tokens: 0,
        }
    }

    fn queued(req: TraceRequest) -> Queued {
        Queued { req, at: Instant::now(), passes: 0 }
    }

    fn shared(policy: Policy) -> Shared {
        Shared {
            queue: VecDeque::new(),
            ready: Vec::new(),
            closed: false,
            aborted: false,
            inflight: 0,
            next_seq: 0,
            policy,
            n_layers: crate::config::TINY.n_layers,
            max_yields: DEFAULT_MAX_YIELDS,
            max_phase_batch: DEFAULT_MAX_PHASE_BATCH,
            model: crate::config::TINY.clone(),
            fpga: u280_fast_prefill(),
        }
    }

    fn meta(seq: u64, priority: Priority) -> ReqMeta {
        ReqMeta {
            seq,
            priority,
            yields: 0,
            submitted_at: Instant::now(),
            queue_us: 0.0,
            parked_at: Instant::now(),
            pipeline_wait_us: 0.0,
            decode_tokens: 0,
            first_token_us: 0.0,
        }
    }

    /// Dense TINY engine (chunked prefill is a dense-only transform; the
    /// scheduler tests here never need sparse indices).
    fn tiny_engine() -> Engine {
        let mut cfg = EngineConfig::new_native(crate::config::TINY.clone());
        cfg.flex = None;
        Engine::new_native(cfg).unwrap()
    }

    /// A parked TINY state at (Qkv, layer 0) with the given class.
    fn parked(engine: &Engine, id: u64, tokens: usize, seq: u64, priority: Priority) -> Pending {
        let state = engine
            .prefill_start(id, &PromptSpec { kind: PromptKind::Random, tokens, seed: 1 }
                .generate())
            .unwrap();
        Pending { unit: Unit::Prefill(state), meta: meta(seq, priority) }
    }

    /// A parked decode unit: runs a short capture-enabled TINY prefill to
    /// completion, then seeds `steps` decode steps from it.
    fn decode_parked(
        engine: &mut Engine,
        id: u64,
        steps: usize,
        seq: u64,
        priority: Priority,
    ) -> Pending {
        let tokens = PromptSpec { kind: PromptKind::Random, tokens: 128, seed: id }.generate();
        let mut st = engine
            .prefill_start_with(
                id,
                &tokens,
                PrefillArgs { chunk_blocks: 0, capture_decode: true },
            )
            .unwrap();
        let mut run = loop {
            if let Some(r) = engine.phase_step(&mut st).unwrap() {
                break r;
            }
        };
        let state = engine.decode_start(id, &run, steps).unwrap();
        run.decode_inputs = None;
        Pending { unit: Unit::Decode { state, run }, meta: meta(seq, priority) }
    }

    #[test]
    fn sjf_picks_shortest() {
        let mut s = shared(Policy::Sjf);
        s.queue.push_back(queued(req(1, 4096)));
        s.queue.push_back(queued(req(2, 1024)));
        s.queue.push_back(queued(req(3, 2048)));
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 2);
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut s = shared(Policy::Fcfs);
        s.queue.push_back(queued(req(1, 4096)));
        s.queue.push_back(queued(req(2, 1024)));
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 1);
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut s = shared(Policy::Fcfs);
        assert!(next_item(&mut s).is_none());
    }

    #[test]
    fn admission_respects_inflight_cap() {
        let mut s = shared(Policy::Fcfs);
        s.queue.push_back(queued(req(1, 256)));
        s.inflight = 2;
        assert!(pick_work(&mut s, 2, true).is_none(), "pipeline full");
        assert!(matches!(pick_work(&mut s, 3, true), Some(Work::Admit(..))));
        assert_eq!(s.inflight, 3);
    }

    #[test]
    fn ready_states_win_over_admission() {
        // a parked state must be stepped before a new request is admitted
        let mut s = shared(Policy::Fcfs);
        s.queue.push_back(queued(req(7, 256)));
        let engine = tiny_engine();
        s.ready.push(parked(&engine, 3, 128, 0, Priority::Interactive));
        s.inflight = 1;
        match pick_work(&mut s, 4, true) {
            Some(Work::Phases(group)) => {
                assert_eq!(group.len(), 1);
                assert_eq!(group[0].unit.request_id(), 3);
            }
            other => panic!("expected a phase step, got {}", match other {
                Some(Work::Admit(..)) => "admission",
                _ => "nothing",
            }),
        }
        // queue untouched
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn preemptive_queue_ranks_class_before_length() {
        let mut s = shared(Policy::Preemptive);
        s.queue.push_back(queued(req_class(1, 256, Priority::Batch)));
        s.queue.push_back(queued(req_class(2, 4096, Priority::Interactive)));
        s.queue.push_back(queued(req_class(3, 1024, Priority::Interactive)));
        // shortest *interactive* first, even though the batch one is shorter
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 3);
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 2);
        let (r, _) = next_item(&mut s).unwrap();
        assert_eq!(r.id, 1);
    }

    #[test]
    fn preemptive_admits_interactive_over_parked_batch() {
        // a parked long batch prefill + a queued short interactive: the
        // interactive jumps the slot and the batch is charged one yield
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        s.ready.push(parked(&engine, 0, 512, 0, Priority::Batch));
        s.inflight = 1;
        s.queue.push_back(queued(req_class(1, 128, Priority::Interactive)));
        match pick_work(&mut s, 4, true) {
            Some(Work::Admit(r, _)) => assert_eq!(r.id, 1),
            _ => panic!("expected the interactive admission to jump the parked batch"),
        }
        assert_eq!(s.ready[0].meta.yields, 1, "the parked batch yielded its slot");
        // under FCFS the same shape steps the parked state instead
        let mut s = shared(Policy::Fcfs);
        s.ready.push(parked(&engine, 0, 512, 0, Priority::Batch));
        s.inflight = 1;
        s.queue.push_back(queued(req_class(1, 128, Priority::Interactive)));
        assert!(matches!(pick_work(&mut s, 4, true), Some(Work::Phases(_))));
    }

    #[test]
    fn preemptive_steps_interactive_before_older_batch() {
        // both parked: the newer interactive leads, the older batch is
        // passed over (charged) at the phase boundary
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        s.ready.push(parked(&engine, 0, 512, 0, Priority::Batch));
        s.ready.push(parked(&engine, 1, 128, 1, Priority::Interactive));
        s.inflight = 2;
        match pick_work(&mut s, 4, false) {
            Some(Work::Phases(group)) => {
                assert_eq!(group[0].unit.request_id(), 1);
            }
            _ => panic!("expected a phase step"),
        }
        assert_eq!(s.ready.len(), 1);
        assert_eq!(s.ready[0].unit.request_id(), 0);
        assert_eq!(s.ready[0].meta.yields, 1);
    }

    #[test]
    fn aged_batch_outranks_interactive_work() {
        // a batch state at the aging bound runs ahead of a queued AND a
        // parked interactive — the starvation bound in action
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        s.max_yields = 3;
        let mut batch = parked(&engine, 0, 512, 0, Priority::Batch);
        batch.meta.yields = 3;
        s.ready.push(batch);
        s.ready.push(parked(&engine, 1, 128, 1, Priority::Interactive));
        s.inflight = 2;
        s.queue.push_back(queued(req_class(2, 128, Priority::Interactive)));
        match pick_work(&mut s, 8, false) {
            Some(Work::Phases(group)) => assert_eq!(group[0].unit.request_id(), 0),
            _ => panic!("expected the aged batch to step"),
        }
        // the aged batch accrues no further yields and nothing was charged
        assert_eq!(s.ready[0].meta.yields, 0, "newer interactive is not charged");
    }

    #[test]
    fn preemptive_respects_inflight_cap() {
        // a queued interactive outranks the parked batch but the pipeline
        // is full: the batch steps (states are never evicted)
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        s.ready.push(parked(&engine, 0, 512, 0, Priority::Batch));
        s.inflight = 1;
        s.queue.push_back(queued(req_class(1, 128, Priority::Interactive)));
        match pick_work(&mut s, 1, true) {
            Some(Work::Phases(group)) => assert_eq!(group[0].unit.request_id(), 0),
            _ => panic!("expected the parked batch to step when the pipeline is full"),
        }
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn queued_batch_ages_to_admission_under_interactive_stream() {
        // regression: a Batch request that never wins admission must be
        // covered by the aging bound. A parked interactive keeps winning
        // phase slots; each pick charges the queued batch one pass, and
        // at the bound it ages to class 0 and jumps the interactive.
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        s.max_yields = 2;
        s.queue.push_back(queued(req_class(9, 4096, Priority::Batch)));
        s.ready.push(parked(&engine, 0, 128, 0, Priority::Interactive));
        s.inflight = 1;
        for turn in 0..2u64 {
            match pick_work(&mut s, 4, false) {
                Some(Work::Phases(group)) => {
                    assert_eq!(group[0].unit.request_id(), 0);
                    // park the state back, as the worker loop would
                    s.ready.extend(group);
                }
                _ => panic!("expected the interactive phase step on turn {turn}"),
            }
            assert_eq!(s.queue[0].passes, turn + 1, "each pick charges one pass");
        }
        match pick_work(&mut s, 4, false) {
            Some(Work::Admit(r, _)) => assert_eq!(r.id, 9),
            _ => panic!("expected the aged queued batch to win admission"),
        }
    }

    #[test]
    fn remaining_cost_prefers_advanced_states_within_class() {
        // same class, same context: the state further along (smaller
        // remaining cost) leads, so started work drains
        let engine = tiny_engine();
        let mut s = shared(Policy::Preemptive);
        let fresh = parked(&engine, 0, 256, 0, Priority::Interactive);
        let mut advanced = parked(&engine, 1, 256, 1, Priority::Interactive);
        // walk request 1 one full phase ahead
        let mut eng = tiny_engine();
        eng.phase_step(advanced.unit.prefill_mut()).unwrap();
        assert!(advanced.unit.remaining_cost() < fresh.unit.remaining_cost());
        s.ready.push(fresh);
        s.ready.push(advanced);
        s.inflight = 2;
        match pick_work(&mut s, 4, false) {
            Some(Work::Phases(group)) => assert_eq!(group[0].unit.request_id(), 1),
            _ => panic!("expected a phase step"),
        }
        // equal class and the winner is *newer*: no yield charged to the
        // older same-class state
        assert_eq!(s.ready[0].meta.yields, 0);
    }

    #[test]
    fn decode_steps_lead_under_every_policy() {
        // a parked decode step (one pending token) outranks parked
        // prefill work — FCFS progress order and the preemptive rank
        // (Interactive-class, near-zero remaining cost) agree, even when
        // the decoding request was admitted as Batch
        let mut engine = tiny_engine();
        for policy in [Policy::Fcfs, Policy::Preemptive] {
            let mut s = shared(policy);
            s.ready.push(parked(&engine, 0, 256, 0, Priority::Interactive));
            s.ready.push(decode_parked(&mut engine, 1, 4, 1, Priority::Batch));
            s.inflight = 2;
            match pick_work(&mut s, 4, false) {
                Some(Work::Phases(group)) => {
                    assert_eq!(group[0].unit.request_id(), 1, "{policy:?}");
                    assert!(matches!(group[0].unit, Unit::Decode { .. }));
                }
                _ => panic!("expected the decode step to lead under {policy:?}"),
            }
        }
    }

    #[test]
    fn form_group_fuses_decode_lanes_and_never_mixes() {
        let mut engine = tiny_engine();
        let mut s = shared(Policy::Fcfs);
        let lead = decode_parked(&mut engine, 0, 4, 0, Priority::Interactive);
        s.ready.push(decode_parked(&mut engine, 1, 4, 1, Priority::Interactive));
        s.ready.push(parked(&engine, 2, 256, 2, Priority::Interactive));
        s.inflight = 3;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 2, "co-resident decode lanes fuse");
        assert!(group.iter().all(|p| matches!(p.unit, Unit::Decode { .. })));
        assert_eq!(s.ready.len(), 1, "the prefill lane stays parked");
        // and a prefill lead never picks up a parked decode lane
        let mut s = shared(Policy::Fcfs);
        let lead = parked(&engine, 3, 256, 0, Priority::Interactive);
        s.ready.push(decode_parked(&mut engine, 4, 4, 1, Priority::Interactive));
        s.inflight = 2;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 1, "lifecycles never mix in one fused group");
        assert_eq!(s.ready.len(), 1);
    }

    #[test]
    fn chunked_prefill_slices_solo_step() {
        // a chunked lead never fuses — slices change the priced geometry
        // and the engine's batch phases run full-context lanes only
        let engine = tiny_engine();
        let tokens = PromptSpec { kind: PromptKind::Random, tokens: 256, seed: 5 }.generate();
        let state = engine
            .prefill_start_with(5, &tokens, PrefillArgs { chunk_blocks: 1, capture_decode: false })
            .unwrap();
        assert!(state.chunked());
        let mut s = shared(Policy::Fcfs);
        let lead = Pending { unit: Unit::Prefill(state), meta: meta(0, Priority::Interactive) };
        s.ready.push(parked(&engine, 6, 256, 1, Priority::Interactive));
        s.inflight = 2;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 1, "chunked lead solo-steps");
        assert_eq!(s.ready.len(), 1);
    }

    #[test]
    fn lifecycle_snapshot_reports_every_stage() {
        let mut engine = tiny_engine();
        let mut s = shared(Policy::Fcfs);
        s.queue.push_back(queued(req(7, 256)));
        s.ready.push(parked(&engine, 8, 256, 0, Priority::Interactive));
        s.ready.push(decode_parked(&mut engine, 9, 4, 1, Priority::Interactive));
        assert_eq!(
            lifecycle_snapshot(&s),
            vec![
                (7, Lifecycle::Queued),
                (8, Lifecycle::Prefilling { chunk: 0 }),
                (9, Lifecycle::Decoding { step: 0 }),
            ]
        );
    }

    #[test]
    fn phase_batch_env_values_validate() {
        assert_eq!(parse_phase_batch("4"), Ok(4));
        assert_eq!(parse_phase_batch(" 1 "), Ok(1));
        let zero = parse_phase_batch("0").unwrap_err();
        assert!(zero.contains("must be > 0"), "got: {zero}");
        assert!(parse_phase_batch("three").is_err());
        assert!(parse_phase_batch("-2").is_err());
        assert!(parse_phase_batch("2.5").is_err());
    }

    #[test]
    fn prefill_chunk_env_values_validate() {
        assert_eq!(parse_prefill_chunk("256"), Ok(256));
        assert_eq!(parse_prefill_chunk("0"), Ok(0), "0 disables chunking");
        assert_eq!(parse_prefill_chunk(" 128 "), Ok(128));
        let odd = parse_prefill_chunk("100").unwrap_err();
        assert!(odd.contains("multiple"), "got: {odd}");
        assert!(parse_prefill_chunk("many").is_err());
        assert!(parse_prefill_chunk("-128").is_err());
    }

    #[test]
    fn replicas_env_values_validate() {
        assert_eq!(parse_replicas("1"), Ok(1));
        assert_eq!(parse_replicas(" 4 "), Ok(4));
        let zero = parse_replicas("0").unwrap_err();
        assert!(zero.contains("must be > 0"), "got: {zero}");
        assert!(parse_replicas("four").is_err());
        assert!(parse_replicas("-1").is_err());
        assert!(parse_replicas("1.5").is_err());
    }

    #[test]
    fn builder_defaults_match_new() {
        let b = ServerOptions::builder().build().unwrap();
        let n = ServerOptions::new(1, Policy::Fcfs);
        assert_eq!(b.n_workers, n.n_workers);
        assert_eq!(b.policy, n.policy);
        assert_eq!(b.pipelined, n.pipelined);
        assert_eq!(b.total_threads, n.total_threads);
        assert_eq!(b.max_inflight, n.max_inflight);
        assert_eq!(b.batch_phases, n.batch_phases);
        assert_eq!(b.max_phase_batch, n.max_phase_batch);
        assert_eq!(b.max_yields, n.max_yields);
        assert_eq!(b.adaptive_hints, n.adaptive_hints);
        assert_eq!(b.prefill_chunk, 0);
        assert_eq!(b.replicas, 0, "0 defers to FASTP_REPLICAS (default 1)");
        assert_eq!(ServerOptions::builder().replicas(4).build().unwrap().replicas, 4);
    }

    #[test]
    fn builder_validates_fields() {
        assert!(ServerOptions::builder().n_workers(0).build().is_err());
        let odd = ServerOptions::builder().prefill_chunk(100).build().unwrap_err();
        assert!(odd.contains("multiple"), "got: {odd}");
        assert!(
            ServerOptions::builder().pipelined(false).prefill_chunk(256).build().is_err(),
            "chunking is a pipelined-mode feature"
        );
        let o = ServerOptions::builder()
            .n_workers(2)
            .policy(Policy::Preemptive)
            .prefill_chunk(256)
            .max_phase_batch(2)
            .build()
            .unwrap();
        assert_eq!(o.n_workers, 2);
        assert_eq!(o.policy, Policy::Preemptive);
        assert_eq!(o.prefill_chunk, 256);
        assert_eq!(o.max_phase_batch, 2);
        // the serial preset stays reachable through the builder
        let serial = ServerOptions::builder().pipelined(false).build().unwrap();
        assert!(!serial.pipelined && !serial.adaptive_hints);
    }

    /// Walk a freshly parked TINY state one phase forward (QKV → IndexGen).
    fn parked_at_index_gen(
        engine: &mut Engine,
        id: u64,
        tokens: usize,
        seq: u64,
    ) -> Pending {
        let mut p = parked(engine, id, tokens, seq, Priority::Interactive);
        engine.phase_step(p.unit.prefill_mut()).unwrap();
        assert_eq!(p.unit.prefill().phase(), Phase::IndexGen);
        p
    }

    #[test]
    fn form_group_fuses_index_gen_on_shared_layer() {
        let mut engine = tiny_engine();
        let mut s = shared(Policy::Fcfs);
        let lead = parked_at_index_gen(&mut engine, 0, 256, 0);
        s.ready.push(parked_at_index_gen(&mut engine, 1, 384, 1));
        s.inflight = 2;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 2, "same-layer IndexGen states fuse");
        assert!(group.iter().all(|p| p.unit.prefill().phase() == Phase::IndexGen));
        assert!(s.ready.is_empty());
    }

    #[test]
    fn form_group_width_clamped_by_max_phase_batch() {
        let mut engine = tiny_engine();
        let mut s = shared(Policy::Fcfs);
        s.max_phase_batch = 1;
        let lead = parked_at_index_gen(&mut engine, 0, 256, 0);
        s.ready.push(parked_at_index_gen(&mut engine, 1, 384, 1));
        s.inflight = 2;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 1, "cap 1 disables fusion");
        assert_eq!(s.ready.len(), 1, "candidate stays parked");
    }

    #[test]
    fn form_group_skips_mismatched_phase() {
        let mut engine = tiny_engine();
        let mut s = shared(Policy::Fcfs);
        let lead = parked_at_index_gen(&mut engine, 0, 256, 0);
        // candidate still at QKV: not fusable with an IndexGen lead
        s.ready.push(parked(&engine, 1, 256, 1, Priority::Interactive));
        s.inflight = 2;
        let group = form_group(&mut s, lead, true);
        assert_eq!(group.len(), 1);
        assert_eq!(s.ready.len(), 1);
    }
}
