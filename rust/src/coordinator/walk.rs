//! The canonical schedule-execution spine (the "memory spine").
//!
//! There is exactly **one** definition of how a block-major SAU schedule
//! moves through the liveness cache: [`ScheduleWalk`] iterates the
//! schedule's execution order — wave by wave, (kv_head, block) coordinate
//! by coordinate — and per coordinate visit performs the canonical cache
//! transaction for every participating lane (lookup, admit on miss, one
//! consume per job). Both consumers drive this walk:
//!
//!  * the **functional engine** (`coordinator::engine`) drives it for the
//!    hit/miss/bypass statistics and the per-request HBM attribution it
//!    reports in `PrefillMetrics`;
//!  * the **cycle simulator** (`sim::prefill`) drives it to *price* each
//!    emitted event (fetch bursts, prefetch overlap, per-job compute).
//!
//! Because the walk is the single source of truth, the two sides can no
//! longer diverge: for the same schedule and cache parameters they produce
//! identical [`CacheStats`] (pinned by `rust/tests/memory_spine.rs`).
//!
//! Batch-merged schedules ([`BatchSchedule`]) walk the same way, with one
//! cache per lane: a lane's blocks appear inside the merged sweep in the
//! lane's own ascending block-major order (waves are index-aligned by
//! `build_schedule_batch`), so each lane's cache outcomes are **identical
//! to its solo walk** — batching changes timing, never per-request stats.

use crate::coordinator::joblist::{cache_key, BatchSchedule, Schedule};
use crate::kvcache::{Access, CacheStats, LivenessCache, Tier};

/// Cache outcome of one lane's visit to a KV-block coordinate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockOutcome {
    /// Resident at lookup time (no HBM fetch).
    Hit(Tier),
    /// Missed, fetched from HBM, and retained in the given tier.
    Fetched(Tier),
    /// Missed and fetched, but not retained (cache full of live blocks,
    /// dead-on-arrival, or disabled cache).
    Bypassed,
}

impl BlockOutcome {
    /// True when this visit moves the block over HBM.
    pub fn is_fetch(&self) -> bool {
        !matches!(self, BlockOutcome::Hit(_))
    }
}

/// One lane's participation in a coordinate visit.
#[derive(Clone, Copy, Debug)]
pub struct LaneVisit {
    /// Request lane (0 for solo schedules).
    pub lane: u16,
    /// Jobs this lane consumes from the block at this visit.
    pub jobs: u32,
    pub outcome: BlockOutcome,
}

/// One spine event: a (wave, kv-block) coordinate visit with every
/// participating lane's job count and cache outcome, in execution order.
#[derive(Debug)]
pub struct BlockVisit<'a> {
    /// Wave index within the schedule (merged wave index for batches).
    pub wave: usize,
    pub kv_head: u16,
    pub block: u32,
    /// Participating lanes in ascending lane order (>= 1 entry).
    pub lanes: &'a [LaneVisit],
}

impl BlockVisit<'_> {
    pub fn total_jobs(&self) -> u64 {
        self.lanes.iter().map(|l| l.jobs as u64).sum()
    }

    /// Lanes whose visit fetches the block from HBM (miss or bypass).
    pub fn fetching_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.outcome.is_fetch()).count()
    }
}

enum Source<'a> {
    Solo(&'a Schedule),
    Batch(&'a BatchSchedule),
}

/// The canonical walk over one schedule's execution order. Construct with
/// [`ScheduleWalk::solo`] or [`ScheduleWalk::batched`], then [`run`]
/// (event sink) or [`drive`] (stats only) it through per-lane caches.
///
/// [`run`]: ScheduleWalk::run
/// [`drive`]: ScheduleWalk::drive
pub struct ScheduleWalk<'a> {
    src: Source<'a>,
}

impl<'a> ScheduleWalk<'a> {
    pub fn solo(schedule: &'a Schedule) -> ScheduleWalk<'a> {
        ScheduleWalk { src: Source::Solo(schedule) }
    }

    pub fn batched(batch: &'a BatchSchedule) -> ScheduleWalk<'a> {
        ScheduleWalk { src: Source::Batch(batch) }
    }

    /// Number of request lanes this walk spans (1 for solo).
    pub fn lanes(&self) -> usize {
        match &self.src {
            Source::Solo(_) => 1,
            Source::Batch(b) => b.lanes,
        }
    }

    /// Drive the walk through per-lane caches (lane `l`'s traffic goes
    /// through `caches[l]`), emitting one [`BlockVisit`] per coordinate
    /// visit in execution order. Caches must have been seeded with each
    /// lane's schedule use counters (`LivenessCache::init_uses`).
    pub fn run<F: FnMut(&BlockVisit)>(&self, caches: &mut [LivenessCache], mut visit: F) {
        assert_eq!(caches.len(), self.lanes(), "one cache per lane");
        match &self.src {
            Source::Solo(s) => {
                for (wi, wave) in s.waves.iter().enumerate() {
                    for bj in &wave.blocks {
                        let key = cache_key(bj.kv_head, bj.block);
                        let lanes = [LaneVisit {
                            lane: 0,
                            jobs: bj.jobs.len() as u32,
                            outcome: touch(&mut caches[0], key, bj.jobs.len()),
                        }];
                        visit(&BlockVisit {
                            wave: wi,
                            kv_head: bj.kv_head,
                            block: bj.block,
                            lanes: &lanes,
                        });
                    }
                }
            }
            Source::Batch(b) => {
                let mut lanes: Vec<LaneVisit> = Vec::with_capacity(b.lanes);
                let mut jobs_of = vec![0u32; b.lanes];
                for (wi, wave) in b.waves.iter().enumerate() {
                    for bj in &wave.blocks {
                        let key = cache_key(bj.kv_head, bj.block);
                        // count each lane's jobs on this coordinate (jobs
                        // are stored lane-grouped but we don't rely on it)
                        for j in &bj.jobs {
                            jobs_of[j.lane as usize] += 1;
                        }
                        lanes.clear();
                        for (lane, jobs) in jobs_of.iter_mut().enumerate() {
                            if *jobs == 0 {
                                continue;
                            }
                            lanes.push(LaneVisit {
                                lane: lane as u16,
                                jobs: *jobs,
                                outcome: touch(&mut caches[lane], key, *jobs as usize),
                            });
                            *jobs = 0;
                        }
                        visit(&BlockVisit {
                            wave: wi,
                            kv_head: bj.kv_head,
                            block: bj.block,
                            lanes: &lanes,
                        });
                    }
                }
            }
        }
    }

    /// Stats-only walk: drive the caches without an event sink (the
    /// functional engine's use — it only needs the resulting
    /// [`CacheStats`] per lane).
    pub fn drive(&self, caches: &mut [LivenessCache]) {
        self.run(caches, |_| {});
    }
}

/// One lane's canonical block transaction: lookup, admit on miss, one
/// consume per job. This — and nothing else — defines what "cache
/// traffic" means for a schedule.
fn touch(cache: &mut LivenessCache, key: u64, jobs: usize) -> BlockOutcome {
    let outcome = match cache.lookup(key) {
        Access::Hit(t) => BlockOutcome::Hit(t),
        Access::Miss => match cache.admit(key) {
            Some(t) => BlockOutcome::Fetched(t),
            None => BlockOutcome::Bypassed,
        },
    };
    for _ in 0..jobs {
        cache.consume(key);
    }
    outcome
}

/// Convenience for tests and reporting: walk a solo schedule through a
/// fresh cache seeded with its use counters and return the stats.
pub fn solo_walk_stats(schedule: &Schedule, mut cache: LivenessCache) -> CacheStats {
    cache.init_uses(schedule.uses.iter().copied());
    ScheduleWalk::solo(schedule).drive(std::slice::from_mut(&mut cache));
    cache.stats()
}

// ---------------------------------------------------------------------------
// IndexGen stream events
// ---------------------------------------------------------------------------

/// i8 K bytes of one (kv_head, block) tile — the unit the SIGU's K stream
/// moves over HBM. This is the **one** byte constant both the engine's
/// `PrefillMetrics` accounting and `sim::prefill`'s stream pricing use,
/// so their IndexGen numbers agree by construction.
pub fn k_block_bytes(cfg: &crate::config::ModelConfig) -> u64 {
    (crate::config::BLOCK * cfg.d_head) as u64
}

/// One (kv_head, block) step of an IndexGen K stream: the coordinate is
/// streamed from HBM **once** and every lane with that block live scores
/// its Q-hats against it — the IndexGen analogue of [`BlockVisit`].
#[derive(Debug)]
pub struct IndexGenVisit<'a> {
    pub kv_head: u16,
    pub block: u32,
    /// Per-lane score-job counts at this coordinate (`group_size` query
    /// heads per live lane; 0 = the lane is past its last block).
    pub lane_jobs: &'a [u32],
}

/// Priced traffic of one IndexGen stream, solo or fused, derived from the
/// canonical [`IndexGenWalk`] events. Per-lane attribution is
/// deterministic: each streamed coordinate's bytes are charged to the
/// lowest-indexed lane with a job there (so lane shares always sum to the
/// fused total, and every lane's share is bounded by its solo cost).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexGenPricing {
    /// Bytes the fused stream moves: each merged coordinate once.
    pub fused_bytes: u64,
    /// What each lane's solo stream would have moved.
    pub solo_bytes: Vec<u64>,
    /// Each lane's attributed share of the fused stream (sums to
    /// `fused_bytes`).
    pub lane_bytes: Vec<u64>,
    /// Per-lane saving vs solo (`solo_bytes - lane_bytes`, always >= 0).
    pub lane_saved: Vec<u64>,
}

impl IndexGenPricing {
    /// Total bytes saved by fusing vs running every lane solo.
    pub fn saved_bytes(&self) -> u64 {
        self.lane_saved.iter().sum()
    }
}

/// The canonical walk of an IndexGen K stream over one or more fused
/// lanes: for every kv head, blocks stream in ascending order over the
/// merged (longest-lane) extent, and each coordinate is visited **once**
/// with per-lane job counts — like [`BlockVisit`] does for SAU. Both the
/// engine's metrics accounting and the simulator's pricing consume this
/// walk, so IndexGen stats agree warm and cold by construction.
#[derive(Clone, Debug)]
pub struct IndexGenWalk {
    n_kv_heads: usize,
    group_size: usize,
    /// Per-lane streamed block counts (the lane's novel context blocks).
    lane_blocks: Vec<usize>,
}

impl IndexGenWalk {
    pub fn new(n_kv_heads: usize, group_size: usize, lane_blocks: Vec<usize>) -> IndexGenWalk {
        assert!(!lane_blocks.is_empty(), "an IndexGen walk needs at least one lane");
        IndexGenWalk { n_kv_heads, group_size, lane_blocks }
    }

    pub fn lanes(&self) -> usize {
        self.lane_blocks.len()
    }

    /// Blocks the merged stream covers per kv head (the longest lane's).
    pub fn merged_blocks(&self) -> usize {
        self.lane_blocks.iter().copied().max().unwrap_or(0)
    }

    /// Emit every stream coordinate in execution order.
    pub fn run<F: FnMut(&IndexGenVisit)>(&self, mut visit: F) {
        let max_n = self.merged_blocks();
        let mut lane_jobs = vec![0u32; self.lane_blocks.len()];
        for g in 0..self.n_kv_heads {
            for b in 0..max_n {
                for (jobs, &n) in lane_jobs.iter_mut().zip(&self.lane_blocks) {
                    *jobs = if b < n { self.group_size as u32 } else { 0 };
                }
                visit(&IndexGenVisit {
                    kv_head: g as u16,
                    block: b as u32,
                    lane_jobs: &lane_jobs,
                });
            }
        }
    }

    /// Price the stream's HBM reads at `k_block_bytes` per coordinate
    /// (see [`k_block_bytes`]), with deterministic per-lane attribution.
    pub fn price(&self, k_block_bytes: u64) -> IndexGenPricing {
        let lanes = self.lane_blocks.len();
        let mut lane_bytes = vec![0u64; lanes];
        let mut fused_bytes = 0u64;
        self.run(|v| {
            fused_bytes += k_block_bytes;
            if let Some(l) = v.lane_jobs.iter().position(|&j| j > 0) {
                lane_bytes[l] += k_block_bytes;
            }
        });
        let solo_bytes: Vec<u64> = self
            .lane_blocks
            .iter()
            .map(|&n| n as u64 * self.n_kv_heads as u64 * k_block_bytes)
            .collect();
        let lane_saved: Vec<u64> =
            solo_bytes.iter().zip(&lane_bytes).map(|(s, a)| s - a).collect();
        IndexGenPricing { fused_bytes, solo_bytes, lane_bytes, lane_saved }
    }
}

// ---------------------------------------------------------------------------
// Decode step events
// ---------------------------------------------------------------------------

/// i8 K + V bytes of one token's KV rows across every kv head, for one
/// layer — the unit a decode step appends and gathers. Like
/// [`k_block_bytes`], this is the **one** byte constant both the engine's
/// decode counters and `sim::prefill`'s decode pricing use, so their
/// decode traffic numbers agree by construction.
pub fn kv_token_bytes(cfg: &crate::config::ModelConfig) -> u64 {
    2 * (cfg.n_kv_heads * cfg.d_head) as u64
}

/// Priced HBM traffic of one (or a span of) decode step(s).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStepTraffic {
    /// KV gather reads (dense decode attention touches every resident
    /// token's K and V rows, per layer).
    pub read_bytes: u64,
    /// KV append writes (one token's K/V rows per layer).
    pub write_bytes: u64,
}

impl DecodeStepTraffic {
    pub fn add(&mut self, other: DecodeStepTraffic) {
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
    }
}

/// The canonical decode-step traffic derivation — the decode analogue of
/// [`ScheduleWalk`]/[`IndexGenWalk`]: one step at context position `pos`
/// appends the new token's K/V rows (write) and gathers all `pos + 1`
/// resident rows for dense decode attention (read), per layer. Both the
/// engine's per-step counters (`Engine::decode_step`) and the cycle
/// simulator's decode twin (`sim::simulate_decode_steps`) price through
/// this one struct, so engine-vs-sim decode traffic identity holds for
/// mixed prefill+decode traces (pinned by `rust/tests/memory_spine.rs`).
#[derive(Clone, Copy, Debug)]
pub struct DecodeStepWalk {
    n_layers: usize,
    token_bytes: u64,
}

impl DecodeStepWalk {
    pub fn new(cfg: &crate::config::ModelConfig) -> DecodeStepWalk {
        DecodeStepWalk { n_layers: cfg.n_layers, token_bytes: kv_token_bytes(cfg) }
    }

    /// Price one step taken with `pos` tokens resident before the append.
    pub fn price(&self, pos: usize) -> DecodeStepTraffic {
        DecodeStepTraffic {
            read_bytes: self.n_layers as u64 * (pos as u64 + 1) * self.token_bytes,
            write_bytes: self.n_layers as u64 * self.token_bytes,
        }
    }

    /// Price `steps` consecutive steps starting at position `pos0` — the
    /// simulator's whole-sequence entry (sum of the per-step prices).
    pub fn price_span(&self, pos0: usize, steps: usize) -> DecodeStepTraffic {
        let mut total = DecodeStepTraffic::default();
        for i in 0..steps {
            total.add(self.price(pos0 + i));
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::joblist::{build_schedule, build_schedule_batch};
    use crate::flexprefill::{HeadIndex, HeadPattern};

    fn idx(blocks: Vec<Vec<u32>>) -> HeadIndex {
        HeadIndex { pattern: HeadPattern::VerticalSlash, d_js: 0.0, blocks }
    }

    fn seeded_cache(s: &Schedule, blocks: usize) -> LivenessCache {
        let mut c = if blocks > 0 {
            LivenessCache::new(blocks, 0.5, 1)
        } else {
            LivenessCache::disabled()
        };
        c.init_uses(s.uses.iter().copied());
        c
    }

    #[test]
    fn solo_walk_emits_every_coordinate_once_per_wave() {
        let indices = vec![idx(vec![vec![0], vec![0, 1], vec![0, 2], vec![3]])];
        let s = build_schedule(&indices, 1, 2);
        let mut cache = seeded_cache(&s, 4);
        let mut events = 0usize;
        let mut jobs = 0u64;
        ScheduleWalk::solo(&s).run(std::slice::from_mut(&mut cache), |v| {
            events += 1;
            assert_eq!(v.lanes.len(), 1);
            jobs += v.total_jobs();
        });
        let expected_events: usize = s.waves.iter().map(|w| w.blocks.len()).sum();
        assert_eq!(events, expected_events);
        assert_eq!(jobs as usize, s.total_jobs);
        assert_eq!(cache.stats().lookups, expected_events as u64);
    }

    #[test]
    fn batch_walk_per_lane_stats_match_solo_walks() {
        let a_idx = vec![idx(vec![vec![0], vec![0, 1], vec![0, 2], vec![1, 3]])];
        let b_idx = vec![idx(vec![vec![0], vec![1], vec![0, 2]])];
        let a = build_schedule(&a_idx, 1, 2);
        let b = build_schedule(&b_idx, 1, 2);
        let solo_a = solo_walk_stats(&a, LivenessCache::new(2, 0.5, 1));
        let solo_b = solo_walk_stats(&b, LivenessCache::new(2, 0.5, 1));

        let batch = build_schedule_batch(&[&a, &b]);
        let mut caches = vec![seeded_cache(&a, 2), seeded_cache(&b, 2)];
        ScheduleWalk::batched(&batch).drive(&mut caches);
        assert_eq!(caches[0].stats(), solo_a, "lane 0 stats drift under batching");
        assert_eq!(caches[1].stats(), solo_b, "lane 1 stats drift under batching");
    }

    #[test]
    fn batch_walk_groups_lanes_per_coordinate() {
        let a_idx = vec![idx(vec![vec![0], vec![0]])];
        let b_idx = vec![idx(vec![vec![0], vec![0]])];
        let a = build_schedule(&a_idx, 1, 0);
        let b = build_schedule(&b_idx, 1, 0);
        let batch = build_schedule_batch(&[&a, &b]);
        let mut caches = vec![seeded_cache(&a, 2), seeded_cache(&b, 2)];
        let mut visits = Vec::new();
        ScheduleWalk::batched(&batch).run(&mut caches, |v| {
            visits.push((v.block, v.lanes.len(), v.fetching_lanes()));
        });
        // one merged visit to block 0, both lanes participating, both
        // fetching (each lane's KV data is distinct)
        assert_eq!(visits, vec![(0, 2, 2)]);
    }

    #[test]
    fn disabled_cache_walk_counts_bypasses() {
        let indices = vec![idx(vec![vec![0], vec![0]])];
        let s = build_schedule(&indices, 1, 0);
        let stats = solo_walk_stats(&s, LivenessCache::disabled());
        assert_eq!(stats.hits(), 0);
        assert_eq!(stats.misses, 1); // single wave: one visit
        assert_eq!(stats.bypasses, 1);
    }

    #[test]
    fn index_gen_walk_streams_merged_extent_once_per_kv_head() {
        let walk = IndexGenWalk::new(2, 3, vec![4, 6]);
        assert_eq!(walk.merged_blocks(), 6);
        let mut visits = 0usize;
        let mut jobs = 0u64;
        walk.run(|v| {
            visits += 1;
            jobs += v.lane_jobs.iter().map(|&j| j as u64).sum::<u64>();
            // lane 1 is the longer lane: live everywhere
            assert_eq!(v.lane_jobs[1], 3);
            assert_eq!(v.lane_jobs[0], if v.block < 4 { 3 } else { 0 });
        });
        assert_eq!(visits, 2 * 6, "one visit per (kv_head, merged block)");
        // group_size score jobs per live (lane, coordinate)
        assert_eq!(jobs, (2 * (4 + 6) * 3) as u64);
    }

    #[test]
    fn index_gen_pricing_fuses_to_merged_extent_with_exact_attribution() {
        let kb = 1000u64;
        let p = IndexGenWalk::new(2, 3, vec![4, 6]).price(kb);
        assert_eq!(p.fused_bytes, 2 * 6 * kb, "stream once over the merged extent");
        assert_eq!(p.solo_bytes, vec![2 * 4 * kb, 2 * 6 * kb]);
        // lowest-live-lane attribution: lane 0 pays its own blocks, lane 1
        // only the extra tail — shares sum to the fused total
        assert_eq!(p.lane_bytes, vec![2 * 4 * kb, 2 * 2 * kb]);
        assert_eq!(p.lane_saved, vec![0, 2 * 4 * kb]);
        assert_eq!(p.lane_bytes.iter().sum::<u64>(), p.fused_bytes);
        assert_eq!(p.saved_bytes(), 2 * 4 * kb);

        // solo (width 1): fused == solo, nothing saved
        let solo = IndexGenWalk::new(2, 3, vec![5]).price(kb);
        assert_eq!(solo.fused_bytes, 2 * 5 * kb);
        assert_eq!(solo.lane_bytes, vec![2 * 5 * kb]);
        assert_eq!(solo.saved_bytes(), 0);
    }

    #[test]
    fn decode_step_walk_prices_gather_and_append_per_layer() {
        let cfg = crate::config::TINY.clone();
        let tok = kv_token_bytes(&cfg);
        assert_eq!(tok, 2 * (cfg.n_kv_heads * cfg.d_head) as u64);
        let walk = DecodeStepWalk::new(&cfg);
        // step at pos 256: gather 257 resident rows + append 1, per layer
        let t = walk.price(256);
        assert_eq!(t.read_bytes, cfg.n_layers as u64 * 257 * tok);
        assert_eq!(t.write_bytes, cfg.n_layers as u64 * tok);
    }

    #[test]
    fn decode_step_span_is_sum_of_steps() {
        let cfg = crate::config::TINY.clone();
        let walk = DecodeStepWalk::new(&cfg);
        let span = walk.price_span(128, 5);
        let mut sum = DecodeStepTraffic::default();
        for i in 0..5 {
            sum.add(walk.price(128 + i));
        }
        assert_eq!(span, sum);
        // writes are position-independent: steps * per-step append
        assert_eq!(span.write_bytes, 5 * walk.price(0).write_bytes);
    }
}
