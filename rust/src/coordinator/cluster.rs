//! Sharded multi-replica serving (ROADMAP direction 3): N engine
//! replicas behind a cost-model router.
//!
//! A [`Cluster`] spawns `replicas` independent [`Server`]s — each with
//! its own workers, its own share of the total kernel-thread budget, its
//! own [`PrefixStore`] — over **one** shared [`ModelWeights`] instance.
//! A [`Router`] places every arrival on one replica; placement only
//! moves work between identical engines, so per-request outputs are
//! **bit-identical** to single-replica serving for every policy and
//! replica count (the contract the replica-matrix CI legs pin).
//!
//! The router is a **pure function of the submission stream**: it never
//! reads live replica state (queue depths and store contents depend on
//! wall-clock completion timing), but instead maintains deterministic
//! shadow bookkeeping per replica —
//!
//!  * a simulated work clock: each placement appends the request's
//!    simulator-priced cost ([`sim::simulate_prefill_batch_prefixed`])
//!    to the replica's estimated finish queue, and each arrival's
//!    `arrival_us` drains finished estimates, yielding a backlog
//!    estimate and a queue depth;
//!  * a shadow prefix-coverage set: the chain hashes
//!    ([`PrefixStore::chain`]) of every request already placed there.
//!    An arrival's affinity is its consecutive leading-block coverage
//!    against that set — the same walk the real store's lookup performs,
//!    minus the timing-dependent eviction state.
//!
//! Every policy shares this bookkeeping (LeastLoaded needs priced
//! backlogs too); they differ only in the choice rule:
//!
//!  * [`RouterPolicy::RoundRobin`] — `seq % replicas`, the placement-
//!    blind baseline;
//!  * [`RouterPolicy::LeastLoaded`] — minimum estimated backlog;
//!  * [`RouterPolicy::CostModel`] — minimum (backlog + marginal TTFT
//!    estimate), where the marginal estimate is priced at the replica's
//!    prefix coverage, so reuse affinity discounts exactly the replicas
//!    that have served the prefix before. Queue depth breaks cost ties.
//!
//! All ties break to the lowest replica index, so placements are
//! replayable: the same trace under the same options routes identically,
//! forever (pinned by the determinism tests in `tests/replica_cluster`).

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{u280_fast_prefill, FpgaConfig, ModelConfig, BLOCK};
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::prefix::{PrefixConfig, PrefixStore};
use crate::coordinator::server::{env_replicas, Completion, Server, ServerOptions};
use crate::model::forward::suffix_dense_indices;
use crate::model::ModelWeights;
use crate::sim::simulate_prefill_batch_prefixed;
use crate::util::pool::WorkerPool;
use crate::workload::prompts::{RequestTrace, TraceRequest};

/// Replica-placement policy ladder: the cost-model win is only
/// meaningful against dumb baselines measured on the same trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// `seq % replicas` — placement-blind.
    RoundRobin,
    /// Minimum estimated backlog (simulator-priced outstanding work).
    LeastLoaded,
    /// Minimum (backlog + prefix-coverage-discounted marginal TTFT).
    CostModel,
}

impl RouterPolicy {
    pub fn from_name(name: &str) -> Option<RouterPolicy> {
        match name {
            "round_robin" => Some(RouterPolicy::RoundRobin),
            "least_loaded" => Some(RouterPolicy::LeastLoaded),
            "cost_model" => Some(RouterPolicy::CostModel),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::LeastLoaded => "least_loaded",
            RouterPolicy::CostModel => "cost_model",
        }
    }
}

/// One routing decision. `est_cost_us` is the simulator-priced marginal
/// TTFT estimate the chosen replica was charged (coverage-discounted, so
/// a prefix-affine placement prices below a cold one of the same
/// length) — every policy records it, because every policy's backlog
/// bookkeeping is built from it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Placement {
    pub request_id: u64,
    pub replica: usize,
    /// Simulated-clock arrival the decision was made at (us).
    pub arrival_us: u64,
    /// Marginal cost estimate charged to the chosen replica (us).
    pub est_cost_us: f64,
    /// Leading blocks of the request covered by the chosen replica's
    /// shadow prefix set at placement time.
    pub prefix_coverage: usize,
}

/// Deterministic shadow bookkeeping for one replica.
struct ReplicaState {
    /// Estimated finish times (simulated us) of requests placed here and
    /// not yet drained by the clock. The replica is modeled as a serial
    /// device: a new placement starts at `max(now, last finish)`.
    finishes: VecDeque<f64>,
    /// Chain hashes of every full leading block of requests placed here
    /// (minus each request's final block, which always runs novel — the
    /// same cap the real store's lookup applies).
    chains: HashSet<u64>,
}

impl ReplicaState {
    fn new() -> ReplicaState {
        ReplicaState { finishes: VecDeque::new(), chains: HashSet::new() }
    }

    /// Drop finish estimates at or before the simulated clock.
    fn drain(&mut self, now_us: f64) {
        while self.finishes.front().is_some_and(|&f| f <= now_us) {
            self.finishes.pop_front();
        }
    }

    /// Estimated outstanding work at `now_us` (0 when idle).
    fn backlog_us(&self, now_us: f64) -> f64 {
        self.finishes.back().map_or(0.0, |&f| (f - now_us).max(0.0))
    }

    fn queue_depth(&self) -> usize {
        self.finishes.len()
    }
}

/// The pure request router. Feed it arrivals in submission order; it
/// returns replayable placements (same trace + same construction =>
/// same placements, bit-for-bit).
pub struct Router {
    policy: RouterPolicy,
    model: ModelConfig,
    fpga: FpgaConfig,
    /// Hash-only store instance: [`PrefixStore::chain`] takes `&self`,
    /// so one salted hasher serves every routing decision without ever
    /// storing a block.
    hasher: PrefixStore,
    replicas: Vec<ReplicaState>,
    /// Placement sequence number (drives RoundRobin).
    seq: u64,
    /// Simulated clock (us): the latest arrival routed so far.
    clock_us: f64,
    /// Marginal-cost cache keyed by (context blocks, covered blocks) —
    /// traces draw from a few length classes, so pricing is amortized to
    /// a handful of simulator calls per trace.
    cost_cache: std::collections::HashMap<(usize, usize), f64>,
}

impl Router {
    /// A router for `n_replicas` replicas of the engine described by
    /// `cfg`. The chain hasher is salted with the same (model name,
    /// weight seed) the replicas' real stores use, so shadow coverage
    /// walks the same hash space.
    pub fn new(policy: RouterPolicy, n_replicas: usize, cfg: &EngineConfig) -> Router {
        assert!(n_replicas > 0, "a cluster has at least one replica");
        Router {
            policy,
            model: cfg.model.clone(),
            fpga: u280_fast_prefill(),
            hasher: PrefixStore::new(cfg.model.name, cfg.weight_seed, PrefixConfig::default()),
            replicas: (0..n_replicas).map(|_| ReplicaState::new()).collect(),
            seq: 0,
            clock_us: 0.0,
            cost_cache: std::collections::HashMap::new(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Simulator-priced marginal TTFT estimate (us) for a request of
    /// `blocks` full context blocks resuming after `covered` reused
    /// leading blocks. One layer of dense suffix indices suffices — the
    /// simulator cycles index sets across layers. Cached per
    /// (blocks, covered).
    pub fn price_us(&mut self, blocks: usize, covered: usize) -> f64 {
        let blocks = blocks.max(1);
        let covered = covered.min(blocks - 1);
        if let Some(&c) = self.cost_cache.get(&(blocks, covered)) {
            return c;
        }
        let sets = vec![suffix_dense_indices(self.model.n_heads, blocks, covered)];
        let rep = simulate_prefill_batch_prefixed(
            &self.fpga,
            &self.model,
            &[blocks * BLOCK],
            &[sets.as_slice()],
            &[covered],
        );
        let us = rep.combined.ttft_ms * 1e3;
        self.cost_cache.insert((blocks, covered), us);
        us
    }

    /// Consecutive leading-block coverage of `chain` against one
    /// replica's shadow set — the affinity probe.
    fn coverage(replica: &ReplicaState, chain: &[u64]) -> usize {
        chain.iter().take_while(|h| replica.chains.contains(h)).count()
    }

    /// Route one arrival: advance the simulated clock to its
    /// `arrival_us`, score every replica under the policy, charge the
    /// winner the marginal cost, and record its chain hashes in the
    /// winner's shadow set. Pure: depends only on the construction
    /// parameters and the arrivals routed so far.
    pub fn route(&mut self, req: &TraceRequest) -> Placement {
        let now = self.clock_us.max(req.arrival_us as f64);
        self.clock_us = now;
        for r in &mut self.replicas {
            r.drain(now);
        }
        let tokens = req.spec.generate();
        let chain = self.hasher.chain(&tokens);
        let blocks = (tokens.len() / BLOCK).max(1);

        // score = (cost score, queue depth); lowest index wins ties
        let n = self.replicas.len();
        let mut best = 0usize;
        let mut best_score = (f64::INFINITY, usize::MAX);
        let mut best_cost = 0.0f64;
        let mut best_cov = 0usize;
        for i in 0..n {
            let cov = Self::coverage(&self.replicas[i], &chain).min(blocks - 1);
            let marginal = self.price_us(blocks, cov);
            let backlog = self.replicas[i].backlog_us(now);
            let depth = self.replicas[i].queue_depth();
            let score = match self.policy {
                // RoundRobin ignores the scores entirely (handled below)
                RouterPolicy::RoundRobin => (0.0, 0),
                RouterPolicy::LeastLoaded => (backlog, depth),
                RouterPolicy::CostModel => (backlog + marginal, depth),
            };
            let wins = match self.policy {
                RouterPolicy::RoundRobin => i == (self.seq % n as u64) as usize,
                _ => score < best_score,
            };
            if wins {
                best = i;
                best_score = score;
                best_cost = marginal;
                best_cov = cov;
            }
        }

        // charge the winner: serial-device finish estimate + shadow
        // chains (all full leading blocks except the last, which always
        // runs novel — mirroring the engine's publish/lookup cap)
        let winner = &mut self.replicas[best];
        let start = winner.finishes.back().copied().unwrap_or(now).max(now);
        winner.finishes.push_back(start + best_cost);
        let publishable = chain.len().saturating_sub(1);
        winner.chains.extend(chain[..publishable].iter().copied());
        self.seq += 1;
        Placement {
            request_id: req.id,
            replica: best,
            arrival_us: req.arrival_us,
            est_cost_us: best_cost,
            prefix_coverage: best_cov,
        }
    }

    /// Route a whole trace in arrival order (stable on ties, like
    /// [`Server::replay`]) — the replayable placement log for a trace.
    pub fn route_trace(&mut self, trace: &RequestTrace) -> Vec<Placement> {
        let mut reqs = trace.requests.clone();
        reqs.sort_by_key(|r| r.arrival_us);
        reqs.iter().map(|r| self.route(r)).collect()
    }
}

/// The completions and placement log of one drained cluster.
pub struct ClusterRun {
    /// All replicas' completions, merged and sorted by request id.
    pub completions: Vec<Completion>,
    /// Placement log in routing order.
    pub placements: Vec<Placement>,
    /// Replica count the cluster served with.
    pub n_replicas: usize,
}

impl ClusterRun {
    /// Which replica served `request_id` (None if it was never routed).
    pub fn replica_of(&self, request_id: u64) -> Option<usize> {
        self.placements.iter().find(|p| p.request_id == request_id).map(|p| p.replica)
    }

    /// Replica-stamped [`crate::metrics::ServeSample`]s, in request-id
    /// order — what [`crate::metrics::ServeSummary::from_samples`] needs
    /// to aggregate per-replica placement and utilization counters.
    pub fn samples(&self) -> Vec<crate::metrics::ServeSample> {
        self.completions
            .iter()
            .map(|c| {
                let mut s = c.sample();
                s.replica = self.replica_of(c.request_id).unwrap_or(0);
                s
            })
            .collect()
    }

    /// Aggregate summary with per-replica counters padded to the full
    /// cluster width (a replica that served nothing still shows up with
    /// zero requests).
    pub fn summary(&self) -> crate::metrics::ServeSummary {
        crate::metrics::ServeSummary::from_samples_sharded(&self.samples(), self.n_replicas)
    }
}

/// N replica [`Server`]s over one shared weight instance, behind a
/// [`Router`]. Equal thread shares: each replica's workers lease from a
/// private budget of `total_threads / replicas` (min 1), so a replicas=N
/// cluster and a single replica at the same `total_threads` are
/// resource-comparable.
pub struct Cluster {
    servers: Vec<Server>,
    router: Mutex<Router>,
    placements: Mutex<Vec<Placement>>,
}

impl Cluster {
    /// Spawn a cluster, generating the shared weights once.
    pub fn start_with(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        opts: ServerOptions,
        policy: RouterPolicy,
    ) -> Result<Cluster> {
        let weights = Arc::new(ModelWeights::generate(&cfg.model, cfg.weight_seed));
        Cluster::start_with_weights(artifact_dir, cfg, opts, policy, weights)
    }

    /// Spawn a cluster over pre-generated shared weights. The replica
    /// count resolves from [`ServerOptions::replicas`], falling back to
    /// the `FASTP_REPLICAS` env knob (default 1); the thread budget
    /// resolves exactly as [`Server::start_with_weights`] does, then
    /// splits equally across replicas.
    pub fn start_with_weights(
        artifact_dir: std::path::PathBuf,
        cfg: EngineConfig,
        opts: ServerOptions,
        policy: RouterPolicy,
        weights: Arc<ModelWeights>,
    ) -> Result<Cluster> {
        let n_replicas = if opts.replicas > 0 { opts.replicas } else { env_replicas() };
        let total_threads = if opts.total_threads > 0 {
            opts.total_threads
        } else if cfg.threads > 0 {
            cfg.threads
        } else {
            WorkerPool::from_env().threads()
        };
        let share = (total_threads / n_replicas).max(1);
        let mut servers = Vec::with_capacity(n_replicas);
        for _ in 0..n_replicas {
            let mut ropts = opts;
            ropts.replicas = 1;
            ropts.total_threads = share;
            servers.push(Server::start_with_weights(
                artifact_dir.clone(),
                cfg.clone(),
                ropts,
                Arc::clone(&weights),
            )?);
        }
        Ok(Cluster {
            servers,
            router: Mutex::new(Router::new(policy, n_replicas, &cfg)),
            placements: Mutex::new(Vec::new()),
        })
    }

    pub fn n_replicas(&self) -> usize {
        self.servers.len()
    }

    /// Route and enqueue one request (non-blocking).
    pub fn submit(&self, req: TraceRequest) {
        let placement = self.router.lock().unwrap().route(&req);
        self.placements.lock().unwrap().push(placement);
        self.servers[placement.replica].submit(req);
    }

    /// Open-loop trace replay across the cluster: requests are routed
    /// and submitted at their recorded `arrival_us` offsets, in the same
    /// stable arrival order [`Router::route_trace`] prices — so replayed
    /// placements match the pure router's log exactly.
    pub fn replay(&self, trace: &RequestTrace) {
        let t0 = std::time::Instant::now();
        let mut reqs = trace.requests.clone();
        reqs.sort_by_key(|r| r.arrival_us);
        for r in reqs {
            let target = std::time::Duration::from_micros(r.arrival_us);
            let elapsed = t0.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            self.submit(r);
        }
    }

    /// Close every replica's queue and collect all completions plus the
    /// placement log.
    pub fn drain(self) -> Result<ClusterRun> {
        let n_replicas = self.servers.len();
        let mut completions = Vec::new();
        for server in self.servers {
            completions.extend(server.drain()?);
        }
        completions.sort_by_key(|c| c.request_id);
        let placements = self.placements.into_inner().unwrap();
        Ok(ClusterRun { completions, placements, n_replicas })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TINY;
    use crate::workload::prompts::{Priority, PromptKind, PromptSpec};

    fn tiny_cfg() -> EngineConfig {
        EngineConfig::new_native(TINY.clone())
    }

    fn req(id: u64, tokens: usize, arrival_us: u64) -> TraceRequest {
        TraceRequest {
            id,
            spec: PromptSpec { kind: PromptKind::Random, tokens, seed: 100 + id },
            arrival_us,
            priority: Priority::Interactive,
            decode_tokens: 0,
        }
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 3, &tiny_cfg());
        let got: Vec<usize> =
            (0..6).map(|i| r.route(&req(i, 256, i * 10)).replica).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle_replica_and_lowest_index_ties() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 2, &tiny_cfg());
        // all idle: tie breaks to replica 0
        assert_eq!(r.route(&req(0, 512, 0)).replica, 0);
        // replica 0 now carries backlog: the idle replica 1 wins
        assert_eq!(r.route(&req(1, 512, 0)).replica, 1);
        // equal backlogs again: back to replica 0
        assert_eq!(r.route(&req(2, 512, 0)).replica, 0);
    }

    #[test]
    fn clock_drains_backlog_between_sparse_arrivals() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 2, &tiny_cfg());
        let p0 = r.route(&req(0, 512, 0));
        assert_eq!(p0.replica, 0);
        assert!(p0.est_cost_us > 0.0, "marginal cost must be priced");
        // an arrival far beyond the first request's estimated finish
        // sees two idle replicas again -> lowest index
        let late = (p0.est_cost_us as u64) * 10 + 1_000_000;
        assert_eq!(r.route(&req(1, 512, late)).replica, 0);
    }

    #[test]
    fn cost_model_discounts_prefix_affinity() {
        // a long shared prefix with a short novel tail: resuming at the
        // covered replica must price well below a cold placement
        let kind = PromptKind::SharedPrefix { prefix_seed: 7, prefix_blocks: 7 };
        let mk = |id: u64, arrival_us: u64| TraceRequest {
            id,
            spec: PromptSpec { kind, tokens: 8 * BLOCK, seed: 500 + id },
            arrival_us,
            priority: Priority::Interactive,
            decode_tokens: 0,
        };
        let mut r = Router::new(RouterPolicy::CostModel, 2, &tiny_cfg());
        let cold = r.price_us(8, 0);
        let warm = r.price_us(8, 7);
        assert!(warm < cold * 0.5, "warm {warm} vs cold {cold} us");
        let p0 = r.route(&mk(0, 0));
        assert_eq!((p0.replica, p0.prefix_coverage), (0, 0), "first placement is cold");
        // the cohort's chains now live on replica 0's shadow set. Once
        // its backlog has drained, the next cohort member faces two idle
        // replicas — and the coverage-discounted marginal (warm on 0,
        // cold on 1) tips the otherwise-tied choice toward the cohort's
        // replica
        let late = p0.est_cost_us as u64 + 1;
        let p1 = r.route(&mk(1, late));
        assert_eq!(p1.replica, 0, "affinity tips the equal-backlog tie");
        assert_eq!(p1.prefix_coverage, 7);
        assert!(p1.est_cost_us < p0.est_cost_us);
        // an unrelated same-length request arriving while replica 0
        // still owes p1's work goes to the idle replica: no coverage
        // anywhere, so backlog decides
        let p2 = r.route(&req(2, 8 * BLOCK, late));
        assert_eq!((p2.replica, p2.prefix_coverage), (1, 0));
    }

    #[test]
    fn placements_are_replayable() {
        let trace = RequestTrace::generate_mixed(12, &[256, 512, 1024], 1500, 77);
        for policy in
            [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::CostModel]
        {
            let a = Router::new(policy, 3, &tiny_cfg()).route_trace(&trace);
            let b = Router::new(policy, 3, &tiny_cfg()).route_trace(&trace);
            assert_eq!(a, b, "{policy:?} placements must replay bit-identically");
        }
    }

    #[test]
    fn router_policy_names_roundtrip() {
        for p in [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::CostModel] {
            assert_eq!(RouterPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::from_name("best_effort"), None);
    }
}
