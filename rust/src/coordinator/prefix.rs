//! Content-hashed cross-request prefix KV store (ROADMAP direction 2).
//!
//! At production traffic most requests share a system-prompt / few-shot
//! prefix, yet a from-scratch prefill re-pays the full QKV + SAU work for
//! those leading blocks on every request. Under **dense causal** attention
//! a chunk's per-layer KV depends only on the tokens at or before it
//! (RoPE uses absolute positions, quant scales are per-chunk), so the
//! leading blocks' [`ChunkQkv`] state of one request is *bit-identical*
//! to what any other request with the same leading tokens would compute.
//! This store publishes that state per (token-content, block-position)
//! and lets a later request resume its `PrefillState` at the first novel
//! block — the outputs are bit-identical to the cold run by construction.
//!
//! Keying: a **rolling chain hash** over token blocks,
//! `h_0 = fnv(salt ‖ block_0)`, `h_i = fnv(h_{i-1} ‖ block_i)`, where the
//! salt binds the model name and weight seed (KV from one model never
//! resumes another). The chain makes the key positional *and*
//! content-transitive: `h_i` matches iff every token of blocks `0..=i`
//! matches, so a lookup just walks consecutive chain hits. Each hit is
//! additionally verified byte-exact against the stored block's tokens, so
//! serving a wrong prefix needs a genuine 64-bit chain collision *and* an
//! identical token block — i.e. it cannot happen.
//!
//! Sparse (FlexPrefill) mode is **not** prefix-closed: SIGU ranks blocks
//! against the *last* chunk's pooled queries, so early blocks' index sets
//! — and therefore their hidden state after layer 0 — depend on the whole
//! context. The engine only consults the store when `flex` is off.
//!
//! Reuse is *priced*, not just claimed: the engine (and the cycle
//! simulator, through the same [`seed_prefix`] helper) seeds the reused
//! blocks' residency into each layer's [`LivenessCache`] before the
//! schedule walk, so reuse shows up as ordinary priced cache hits in both
//! stat streams — engine-vs-simulator hit-stat identity is preserved by
//! construction.

use std::collections::HashMap;

use crate::config::BLOCK;
use crate::coordinator::joblist::cache_key;
use crate::kvcache::LivenessCache;
use crate::model::forward::ChunkQkv;

/// Eviction policy for the capacity-bounded store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Evict the least-recently-touched block entry.
    Lru,
    /// Liveness-aware: evict the block with the fewest lifetime hits,
    /// breaking ties by recency — the store-level analogue of the KV
    /// cache's remaining-use ranking (heavily shared prefixes survive).
    LivenessAware,
}

impl EvictPolicy {
    pub fn from_name(name: &str) -> Option<EvictPolicy> {
        match name {
            "lru" => Some(EvictPolicy::Lru),
            "liveness" => Some(EvictPolicy::LivenessAware),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::LivenessAware => "liveness",
        }
    }
}

/// Store sizing + policy knobs (carried by `ServerOptions`).
#[derive(Clone, Copy, Debug)]
pub struct PrefixConfig {
    /// Capacity in block entries (each entry holds one block's per-layer
    /// KV). Must be > 0 — "no store" is expressed by not attaching one.
    pub capacity_blocks: usize,
    pub policy: EvictPolicy,
}

impl Default for PrefixConfig {
    fn default() -> Self {
        PrefixConfig { capacity_blocks: 4096, policy: EvictPolicy::LivenessAware }
    }
}

/// Aggregate store statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Requests that consulted the store.
    pub lookups: u64,
    /// Leading blocks served from the store across all lookups.
    pub hit_blocks: u64,
    /// Block entries published (inserted, not counting already-present).
    pub published_blocks: u64,
    /// Entries evicted to make room under the capacity bound.
    pub evictions: u64,
}

/// One published block: its token bytes (verified on every hit) plus the
/// per-layer KV/quant state needed to resume mid-trace.
struct BlockEntry {
    tokens: Vec<u8>,
    layers: Vec<ChunkQkv>,
    /// Lifetime hits (the liveness-aware eviction rank).
    uses: u64,
    /// Last-touched logical time (the LRU eviction rank).
    tick: u64,
}

/// A resolved lookup: the request's full block chain, how many leading
/// blocks the store covers, and the covered blocks' per-layer chunks
/// (`blocks[b][li]`, cloned out under the lock so later eviction cannot
/// invalidate a running resume).
pub struct PrefixHit {
    pub chain: Vec<u64>,
    pub covered: usize,
    pub blocks: Vec<Vec<ChunkQkv>>,
}

/// The content-hashed prefix KV store. One instance is shared (behind a
/// mutex) by every engine of a server; solo engines can attach one too.
pub struct PrefixStore {
    cfg: PrefixConfig,
    salt: u64,
    map: HashMap<u64, BlockEntry>,
    tick: u64,
    stats: PrefixStats,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl PrefixStore {
    /// The salt binds model identity: KV published under one
    /// (model, weight seed) can never hash-match under another.
    pub fn new(model_name: &str, weight_seed: u64, cfg: PrefixConfig) -> PrefixStore {
        assert!(cfg.capacity_blocks > 0, "prefix store capacity must be > 0");
        let salt = fnv1a(fnv1a(FNV_OFFSET, model_name.as_bytes()), &weight_seed.to_le_bytes());
        PrefixStore { cfg, salt, map: HashMap::new(), tick: 0, stats: PrefixStats::default() }
    }

    pub fn config(&self) -> PrefixConfig {
        self.cfg
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    pub fn len_blocks(&self) -> usize {
        self.map.len()
    }

    /// The rolling chain hash over a context's full token blocks
    /// (`chain[i]` covers tokens `0 .. (i+1)*BLOCK`). Trailing partial
    /// blocks are ignored — a partial block is never published or matched,
    /// so a divergence inside the last full block simply ends the chain
    /// walk at that block.
    pub fn chain(&self, tokens: &[u8]) -> Vec<u64> {
        let mut h = self.salt;
        tokens
            .chunks_exact(BLOCK)
            .map(|blk| {
                h = fnv1a(fnv1a(FNV_OFFSET, &h.to_le_bytes()), blk);
                h
            })
            .collect()
    }

    /// Resolve a request against the store: walk consecutive leading
    /// blocks while the chain hash is present *and* the stored tokens
    /// verify byte-exact *and* the entry was published at `n_layers`
    /// depth, cloning the covered blocks' per-layer chunks out. `covered`
    /// is capped at `max_blocks` (the engine passes `n - 1`: the last
    /// block must run novel so the finish phase has fresh hidden rows).
    pub fn lookup(&mut self, tokens: &[u8], max_blocks: usize, n_layers: usize) -> PrefixHit {
        self.stats.lookups += 1;
        self.tick += 1;
        let chain = self.chain(tokens);
        let mut blocks = Vec::new();
        for (b, key) in chain.iter().enumerate().take(max_blocks) {
            let Some(e) = self.map.get_mut(key) else { break };
            if e.layers.len() != n_layers || e.tokens != tokens[b * BLOCK..(b + 1) * BLOCK] {
                break;
            }
            e.uses += 1;
            e.tick = self.tick;
            blocks.push(e.layers.clone());
        }
        self.stats.hit_blocks += blocks.len() as u64;
        PrefixHit { chain, covered: blocks.len(), blocks }
    }

    /// Publish a completed prefill's leading blocks: `per_block[b]` holds
    /// block `b`'s per-layer chunks (`per_block.len() <= chain.len()`).
    /// Already-present keys are skipped (the content is identical by the
    /// bit-identity contract); new entries evict per policy when the
    /// capacity bound is reached.
    pub fn publish(&mut self, chain: &[u64], tokens: &[u8], per_block: Vec<Vec<ChunkQkv>>) {
        assert!(per_block.len() <= chain.len(), "more blocks than chain hashes");
        self.tick += 1;
        for (b, layers) in per_block.into_iter().enumerate() {
            let key = chain[b];
            if self.map.contains_key(&key) {
                continue;
            }
            while self.map.len() >= self.cfg.capacity_blocks {
                self.evict_one();
            }
            self.map.insert(
                key,
                BlockEntry {
                    tokens: tokens[b * BLOCK..(b + 1) * BLOCK].to_vec(),
                    layers,
                    uses: 0,
                    tick: self.tick,
                },
            );
            self.stats.published_blocks += 1;
        }
    }

    fn evict_one(&mut self) {
        let victim = match self.cfg.policy {
            EvictPolicy::Lru => self.map.iter().min_by_key(|(_, e)| e.tick),
            EvictPolicy::LivenessAware => self.map.iter().min_by_key(|(_, e)| (e.uses, e.tick)),
        }
        .map(|(k, _)| *k);
        if let Some(k) = victim {
            self.map.remove(&k);
            self.stats.evictions += 1;
        }
    }
}

/// Seed the reused leading blocks' residency into one layer's liveness
/// cache, ahead of the schedule walk. Every (kv_head, block) coordinate of
/// the prefix is seeded through [`LivenessCache::seed_resident`] —
/// stats-free, capacity- and liveness-respecting — so the walk prices the
/// reuse as ordinary cache hits. The engine and the cycle simulator call
/// this **same** helper on identically derived caches, which is what keeps
/// their hit statistics identical under reuse. Returns the number of
/// coordinates actually seeded (skips price as misses — still correct).
pub fn seed_prefix(cache: &mut LivenessCache, n_kv_heads: usize, prefix_blocks: usize) -> usize {
    let mut seeded = 0;
    for g in 0..n_kv_heads {
        for b in 0..prefix_blocks {
            if cache.seed_resident(cache_key(g as u16, b as u32)) {
                seeded += 1;
            }
        }
    }
    seeded
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{MatF32, MatI8};
    use crate::util::prng::Prng;

    fn chunk(tag: i8) -> ChunkQkv {
        ChunkQkv {
            q: vec![MatI8::from_vec(1, 1, vec![tag])],
            qs: tag as f32,
            k: vec![MatI8::from_vec(1, 1, vec![tag])],
            ks: 1.0,
            v: vec![MatI8::from_vec(1, 1, vec![tag])],
            vs: 1.0,
            qpool: MatF32::zeros(1, 1),
            kpool: MatF32::zeros(1, 1),
        }
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    fn store(cap: usize, policy: EvictPolicy) -> PrefixStore {
        PrefixStore::new("tiny", 42, PrefixConfig { capacity_blocks: cap, policy })
    }

    /// Publish `n` blocks of `toks` with per-layer tag chunks.
    fn publish_all(s: &mut PrefixStore, toks: &[u8], n_layers: usize) {
        let chain = s.chain(toks);
        let n = chain.len();
        let per_block: Vec<Vec<ChunkQkv>> =
            (0..n).map(|b| (0..n_layers).map(|li| chunk((b * 7 + li) as i8)).collect()).collect();
        s.publish(&chain, toks, per_block);
    }

    #[test]
    fn chain_is_a_prefix_hash() {
        let s = store(64, EvictPolicy::Lru);
        let a = tokens(4 * BLOCK, 1);
        let mut b = a.clone();
        // diverge inside block 2
        b[2 * BLOCK + 17] ^= 0xFF;
        let (ca, cb) = (s.chain(&a), s.chain(&b));
        assert_eq!(ca.len(), 4);
        assert_eq!(ca[..2], cb[..2], "shared leading blocks share hashes");
        assert_ne!(ca[2], cb[2]);
        assert_ne!(ca[3], cb[3], "divergence propagates down the chain");
        // salt binds model identity
        let other = PrefixStore::new("tiny", 43, PrefixConfig::default());
        assert_ne!(ca[0], other.chain(&a)[0]);
    }

    #[test]
    fn publish_then_lookup_roundtrip() {
        let mut s = store(64, EvictPolicy::Lru);
        let toks = tokens(4 * BLOCK, 2);
        publish_all(&mut s, &toks, 2);
        assert_eq!(s.len_blocks(), 4);
        // same leading content, novel tail
        let mut req = toks[..3 * BLOCK].to_vec();
        req.extend(tokens(2 * BLOCK, 99));
        let hit = s.lookup(&req, req.len() / BLOCK - 1, 2);
        assert_eq!(hit.covered, 3);
        assert_eq!(hit.blocks.len(), 3);
        assert_eq!(hit.blocks[1][0].qs, 7.0, "block 1, layer 0 tag");
        assert_eq!(hit.blocks[2][1].qs, 15.0, "block 2, layer 1 tag");
        let st = s.stats();
        assert_eq!((st.lookups, st.hit_blocks, st.published_blocks), (1, 3, 4));
        // covered is capped by max_blocks
        let capped = s.lookup(&toks, 2, 2);
        assert_eq!(capped.covered, 2);
    }

    #[test]
    fn partial_block_divergence_stops_the_walk() {
        let mut s = store(64, EvictPolicy::Lru);
        let toks = tokens(4 * BLOCK, 3);
        publish_all(&mut s, &toks, 1);
        let mut req = toks.clone();
        req[2 * BLOCK + 5] ^= 1; // one byte into block 2
        let hit = s.lookup(&req, 4, 1);
        assert_eq!(hit.covered, 2, "walk ends at the first divergent block");
        // layer-depth mismatch also refuses the entry
        let wrong_depth = s.lookup(&toks, 4, 3);
        assert_eq!(wrong_depth.covered, 0);
    }

    #[test]
    fn eviction_respects_capacity_and_policy() {
        // LRU: the least-recently-touched entry falls out first
        let mut s = store(2, EvictPolicy::Lru);
        let (d1, d2, d3) = (tokens(BLOCK, 41), tokens(BLOCK, 42), tokens(BLOCK, 43));
        publish_all(&mut s, &d1, 1);
        publish_all(&mut s, &d2, 1);
        s.lookup(&d1, 1, 1); // refresh d1's recency
        publish_all(&mut s, &d3, 1); // evicts d2 (stalest tick)
        assert_eq!(s.len_blocks(), 2);
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.lookup(&d1, 1, 1).covered, 1, "recently touched survives");
        assert_eq!(s.lookup(&d2, 1, 1).covered, 0, "stalest entry evicted");
        assert_eq!(s.lookup(&d3, 1, 1).covered, 1);

        // LivenessAware: hit-hot leading blocks survive fresh unused inserts
        let mut s = store(4, EvictPolicy::LivenessAware);
        let a = tokens(4 * BLOCK, 4);
        publish_all(&mut s, &a, 1);
        s.lookup(&a, 2, 1); // leading 2 blocks gain a use; trailing 2 stay at 0
        let c = tokens(2 * BLOCK, 6);
        publish_all(&mut s, &c, 1); // evicts the two zero-use trailing blocks
        assert_eq!(s.len_blocks(), 4);
        assert_eq!(s.stats().evictions, 2);
        assert_eq!(s.lookup(&a, 4, 1).covered, 2, "hit-hot leading blocks survive");
        assert_eq!(s.lookup(&c, 2, 1).covered, 2);
    }

    #[test]
    fn seed_prefix_marks_schedule_residency() {
        use crate::coordinator::joblist::build_schedule;
        use crate::kvcache::Access;
        use crate::model::forward::suffix_dense_indices;
        // 4 blocks, resume at 2, 1 kv head
        let indices = suffix_dense_indices(1, 4, 2);
        let schedule = build_schedule(&indices, 1, 0);
        let mut cache =
            crate::kvcache::layer_cache(64, 0.5, 0.5, 4, 1, schedule.uses.iter().copied());
        let seeded = seed_prefix(&mut cache, schedule.n_kv_heads, 2);
        assert_eq!(seeded, 2);
        assert_eq!(cache.lookup(cache_key(0, 0)), Access::Hit(crate::kvcache::Tier::Cold));
        assert!(matches!(cache.lookup(cache_key(0, 1)), Access::Hit(_)));
        assert_eq!(cache.lookup(cache_key(0, 3)), Access::Miss);
        cache.check_invariants().unwrap();
    }
}
