//! Lookahead prefetch FSM (paper §IV-C): walks the upcoming KV blocks of
//! the block-major schedule in a bounded window, consults the remaining-use
//! counters, and issues fetches only when the target tier has space — so
//! prefetching never displaces a live block and blocks arrive "neither too
//! early nor too late".
//!
//! The simulator uses the aggregate overlap model in `sim::prefill`; this
//! unit is the cycle-free functional FSM: given the schedule order it
//! decides, step by step, which fetch to issue next, and its decisions are
//! property-tested against the safety rules the paper states.

use super::LivenessCache;

/// A prefetch decision for one lookahead step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Issue the fetch now (space available, block live, not resident).
    Fetch(u64),
    /// Skip permanently: the block has zero remaining uses.
    SkipDead(u64),
    /// Skip: already resident.
    SkipResident(u64),
    /// Stall: block is live but no space — retry after evictions.
    Stall(u64),
}

/// Bounded-lookahead prefetcher over an upcoming-key stream.
#[derive(Clone, Debug)]
pub struct Prefetcher {
    pub lookahead: usize,
    /// Upcoming cache keys in schedule order (front = next to execute).
    window: std::collections::VecDeque<u64>,
}

impl Prefetcher {
    pub fn new(lookahead: usize) -> Self {
        Prefetcher { lookahead: lookahead.max(1), window: Default::default() }
    }

    /// Feed the next scheduled key (from the job list walker).
    pub fn push(&mut self, key: u64) {
        self.window.push_back(key);
    }

    pub fn pending(&self) -> usize {
        self.window.len()
    }

    /// Evaluate the head of the window against the cache. Consumes the head
    /// on everything except `Stall`.
    pub fn step(&mut self, cache: &LivenessCache) -> Option<Decision> {
        let &key = self.window.front()?;
        if self.window.len() > self.lookahead {
            // window overflow: the executor is behind; drop to lookahead
            // depth by treating the overflow head as an immediate demand
            // fetch (handled by the executor), not a prefetch.
            self.window.pop_front();
            return self.step(cache);
        }
        let d = if cache.remaining_uses(key) == 0 {
            self.window.pop_front();
            Decision::SkipDead(key)
        } else if cache.is_resident(key) {
            self.window.pop_front();
            Decision::SkipResident(key)
        } else if cache.has_space_for(key) {
            self.window.pop_front();
            Decision::Fetch(key)
        } else {
            Decision::Stall(key)
        };
        Some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::LivenessCache;
    use crate::util::prng::Prng;
    use crate::util::prop::forall_ck;

    fn cache_with(uses: &[(u64, u32)], cap: usize) -> LivenessCache {
        let mut c = LivenessCache::new(cap, 0.5, 2);
        c.init_uses(uses.iter().copied());
        c
    }

    #[test]
    fn fetches_live_nonresident_blocks() {
        let c = cache_with(&[(1, 3)], 4);
        let mut p = Prefetcher::new(4);
        p.push(1);
        assert_eq!(p.step(&c), Some(Decision::Fetch(1)));
        assert_eq!(p.step(&c), None);
    }

    #[test]
    fn skips_dead_and_resident() {
        let mut c = cache_with(&[(1, 1), (2, 3)], 4);
        c.admit(2);
        let mut p = Prefetcher::new(4);
        p.push(99); // never registered -> dead
        p.push(2);
        assert_eq!(p.step(&c), Some(Decision::SkipDead(99)));
        assert_eq!(p.step(&c), Some(Decision::SkipResident(2)));
    }

    #[test]
    fn stalls_when_no_space_and_retries() {
        let mut c = cache_with(&[(1, 9), (2, 9), (3, 9)], 2);
        c.admit(1);
        c.admit(2);
        let mut p = Prefetcher::new(4);
        p.push(3);
        assert_eq!(p.step(&c), Some(Decision::Stall(3)));
        assert_eq!(p.pending(), 1, "stall must not consume");
        // free a slot via evict-on-nil
        for _ in 0..9 {
            c.consume(1);
        }
        assert_eq!(p.step(&c), Some(Decision::Fetch(3)));
    }

    #[test]
    fn prop_prefetch_safety() {
        // Over random schedules: a Fetch decision is only ever issued for a
        // live, non-resident block with space — the paper's safety rules.
        forall_ck(
            0x9FE7C4,
            40,
            |rng: &mut Prng, size| {
                let n_keys = 2 + size % 16;
                let uses: Vec<(u64, u32)> =
                    (0..n_keys).map(|k| (k as u64, 1 + rng.below(4) as u32)).collect();
                let mut stream: Vec<u64> = Vec::new();
                for (k, u) in &uses {
                    for _ in 0..*u {
                        stream.push(*k);
                    }
                }
                rng.shuffle(&mut stream);
                let cap = rng.below(n_keys + 1);
                (uses, stream, cap)
            },
            |(uses, stream, cap)| {
                let mut cache = cache_with(uses, *cap);
                let mut p = Prefetcher::new(4);
                let mut it = stream.iter();
                loop {
                    while p.pending() < p.lookahead {
                        match it.next() {
                            Some(&k) => p.push(k),
                            None => break,
                        }
                    }
                    match p.step(&cache) {
                        None => break,
                        Some(Decision::Fetch(k)) => {
                            if cache.remaining_uses(k) == 0 {
                                return Err("fetched dead block".into());
                            }
                            if cache.is_resident(k) {
                                return Err("refetched resident block".into());
                            }
                            if cache.admit(k).is_none() {
                                return Err("fetch issued without space".into());
                            }
                            cache.consume(k);
                        }
                        Some(Decision::Stall(k)) => {
                            // executor makes progress: demand-consume the
                            // stalled block without retaining it
                            cache.consume(k);
                            // drop it from the window to avoid livelock
                            p.window.pop_front();
                        }
                        Some(Decision::SkipResident(k)) => {
                            cache.consume(k);
                        }
                        Some(Decision::SkipDead(_)) => {}
                    }
                    cache.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
