//! Liveness-driven dual-tier KV block cache (paper §IV-C).
//!
//! Residency policy:
//!  * **exact remaining-use counters** — computed from the job list during
//!    bucketization, each consumption decrements; a counter reaching zero
//!    proves the block is dead for the rest of the sparse-attention step
//!    (**evict-on-nil** — the only eviction; a live block is never evicted);
//!  * **dual tiers** — blocks whose remaining use exceeds `t_hot` (50% of
//!    the query blocks in the paper) are admitted to the Hot tier, others
//!    to the Cold tier, preventing moderately-reused blocks from thrashing
//!    heavily-reused ones;
//!  * **bypass** — if the target tier has no free or dead slot, the block
//!    bypasses the cache entirely (it is still consumed, just not retained).
//!
//! The same structure is used functionally by the coordinator (producing
//! the hit/miss trace) and by the cycle simulator (timing each outcome).
//! Keys are opaque u64s; the coordinator packs (kv_head, block).

pub mod prefetch;

pub use prefetch::{Decision, Prefetcher};

use std::collections::HashMap;

/// Which tier a resident block occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Hot,
    Cold,
}

/// Aggregate cache statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits_hot: u64,
    pub hits_cold: u64,
    pub misses: u64,
    pub admissions_hot: u64,
    pub admissions_cold: u64,
    pub bypasses: u64,
    pub evictions_nil: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits_hot + self.hits_cold
    }
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits() as f64 / self.lookups as f64
    }
}

/// The result of a lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Hit(Tier),
    Miss,
}

/// Build one layer's liveness cache the way **both** spine consumers
/// (the functional engine and the cycle simulator) must: `capacity`
/// block slots (0 = the cacheless ablation), `hot_fraction` tier split,
/// the hot-admission threshold expressed as `t_hot_frac` of the per-key
/// maximum consumer count (`n_blocks` query blocks x GQA `group_size`),
/// seeded with the schedule's exact use counters. Keeping this
/// derivation in one place is part of the memory-spine contract — a
/// consumer deriving its own t_hot would silently diverge.
pub fn layer_cache(
    capacity_blocks: usize,
    hot_fraction: f64,
    t_hot_frac: f64,
    n_blocks: usize,
    group_size: usize,
    uses: impl IntoIterator<Item = (u64, u32)>,
) -> LivenessCache {
    let t_hot = (t_hot_frac * (n_blocks * group_size) as f64) as u32;
    let mut cache = if capacity_blocks > 0 {
        LivenessCache::new(capacity_blocks, hot_fraction, t_hot)
    } else {
        LivenessCache::disabled()
    };
    cache.init_uses(uses);
    cache
}

/// Liveness-driven dual-tier cache over fixed-size KV blocks.
#[derive(Clone, Debug)]
pub struct LivenessCache {
    cap_hot: usize,
    cap_cold: usize,
    t_hot: u32,
    resident: HashMap<u64, Tier>,
    hot_used: usize,
    cold_used: usize,
    remaining: HashMap<u64, u32>,
    stats: CacheStats,
}

impl LivenessCache {
    /// `capacity_blocks` total block slots, split by `hot_fraction`;
    /// `t_hot` is the remaining-use admission threshold for the hot tier.
    pub fn new(capacity_blocks: usize, hot_fraction: f64, t_hot: u32) -> Self {
        let cap_hot = (capacity_blocks as f64 * hot_fraction).round() as usize;
        LivenessCache {
            cap_hot,
            cap_cold: capacity_blocks - cap_hot,
            t_hot,
            resident: HashMap::new(),
            hot_used: 0,
            cold_used: 0,
            remaining: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    /// Disabled cache (Fig. 7 cacheless ablation).
    pub fn disabled() -> Self {
        Self::new(0, 0.5, 0)
    }

    pub fn capacity(&self) -> usize {
        self.cap_hot + self.cap_cold
    }

    /// Install the exact remaining-use counters for the upcoming sparse
    /// attention step (from job-list bucketization). Clears residency.
    pub fn init_uses(&mut self, uses: impl IntoIterator<Item = (u64, u32)>) {
        self.resident.clear();
        self.hot_used = 0;
        self.cold_used = 0;
        self.remaining = uses.into_iter().collect();
    }

    pub fn remaining_uses(&self, key: u64) -> u32 {
        self.remaining.get(&key).copied().unwrap_or(0)
    }

    /// Number of keys with live remaining-use counters (diagnostics — the
    /// regression guard for the unbounded-growth `consume` bug).
    pub fn tracked_keys(&self) -> usize {
        self.remaining.len()
    }

    pub fn is_resident(&self, key: u64) -> bool {
        self.resident.contains_key(&key)
    }

    /// Look a block up, recording hit/miss. A miss does not admit — call
    /// [`admit`] after fetching.
    pub fn lookup(&mut self, key: u64) -> Access {
        self.stats.lookups += 1;
        match self.resident.get(&key) {
            Some(Tier::Hot) => {
                self.stats.hits_hot += 1;
                Access::Hit(Tier::Hot)
            }
            Some(Tier::Cold) => {
                self.stats.hits_cold += 1;
                Access::Hit(Tier::Cold)
            }
            None => {
                self.stats.misses += 1;
                Access::Miss
            }
        }
    }

    fn tier_for(&self, key: u64) -> Tier {
        if self.remaining_uses(key) > self.t_hot {
            Tier::Hot
        } else {
            Tier::Cold
        }
    }

    fn free_slots(&self, tier: Tier) -> usize {
        match tier {
            Tier::Hot => self.cap_hot - self.hot_used,
            Tier::Cold => self.cap_cold - self.cold_used,
        }
    }

    /// Try to retain a freshly fetched block. Returns the tier on success,
    /// None on bypass. Never evicts a live block.
    pub fn admit(&mut self, key: u64) -> Option<Tier> {
        if self.is_resident(key) {
            return self.resident.get(&key).copied();
        }
        if self.remaining_uses(key) == 0 {
            // dead on arrival — retaining it is pure waste
            self.stats.bypasses += 1;
            return None;
        }
        let tier = self.tier_for(key);
        if self.free_slots(tier) == 0 {
            // try the other tier before bypassing (cold-spill), matching the
            // paper's "placed in the cold region or bypass entirely"
            let alt = match tier {
                Tier::Hot => Tier::Cold,
                Tier::Cold => return self.bypass(),
            };
            if self.free_slots(alt) == 0 {
                return self.bypass();
            }
            self.insert(key, alt);
            return Some(alt);
        }
        self.insert(key, tier);
        Some(tier)
    }

    fn bypass(&mut self) -> Option<Tier> {
        self.stats.bypasses += 1;
        None
    }

    fn insert(&mut self, key: u64, tier: Tier) {
        match tier {
            Tier::Hot => {
                self.hot_used += 1;
                self.stats.admissions_hot += 1;
            }
            Tier::Cold => {
                self.cold_used += 1;
                self.stats.admissions_cold += 1;
            }
        }
        self.resident.insert(key, tier);
    }

    /// Seed residency for a block whose payload is already on hand
    /// (cross-request prefix KV reuse): insert it **without** touching the
    /// lookup/admission statistics, so the subsequent schedule walk prices
    /// the reuse as ordinary cache hits — in the engine and the simulator
    /// alike. Same liveness and capacity rules as [`LivenessCache::admit`]
    /// (dead keys and full tiers are skipped, hot seeds spill cold); a
    /// skipped seed simply prices as a miss later, which is still correct.
    /// Call after [`LivenessCache::init_uses`] (which clears residency).
    /// Returns whether the key is resident afterwards.
    pub fn seed_resident(&mut self, key: u64) -> bool {
        if self.is_resident(key) {
            return true;
        }
        if self.remaining_uses(key) == 0 {
            return false;
        }
        let tier = self.tier_for(key);
        let tier = if self.free_slots(tier) > 0 {
            tier
        } else if tier == Tier::Hot && self.free_slots(Tier::Cold) > 0 {
            Tier::Cold
        } else {
            return false;
        };
        match tier {
            Tier::Hot => self.hot_used += 1,
            Tier::Cold => self.cold_used += 1,
        }
        self.resident.insert(key, tier);
        true
    }

    /// Record one consumption of the block (one SAU job). When the counter
    /// reaches zero the block is provably dead, its slot is freed
    /// (evict-on-nil) and its counter entry is dropped. Consuming a key
    /// that was never registered (or is already dead) is a **no-op** — it
    /// must not insert a permanent zero entry, or a long-lived cache
    /// walked over many schedules grows without bound.
    pub fn consume(&mut self, key: u64) {
        let Some(rem) = self.remaining.get_mut(&key) else {
            return;
        };
        debug_assert!(*rem > 0, "consuming block {key} with zero remaining uses");
        *rem = rem.saturating_sub(1);
        if *rem == 0 {
            self.remaining.remove(&key);
            if let Some(tier) = self.resident.remove(&key) {
                match tier {
                    Tier::Hot => self.hot_used -= 1,
                    Tier::Cold => self.cold_used -= 1,
                }
                self.stats.evictions_nil += 1;
            }
        }
    }

    /// True if a prefetch of `key` could be retained right now (used by the
    /// lookahead FSM — prefetches are issued only when space is available,
    /// so live blocks are never displaced).
    pub fn has_space_for(&self, key: u64) -> bool {
        if self.is_resident(key) {
            return false; // already here; no fetch needed
        }
        if self.remaining_uses(key) == 0 {
            return false;
        }
        let tier = self.tier_for(key);
        self.free_slots(tier) > 0
            || (tier == Tier::Hot && self.free_slots(Tier::Cold) > 0)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Structural invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.hot_used > self.cap_hot {
            return Err(format!("hot overflow {}/{}", self.hot_used, self.cap_hot));
        }
        if self.cold_used > self.cap_cold {
            return Err(format!("cold overflow {}/{}", self.cold_used, self.cap_cold));
        }
        let hot = self.resident.values().filter(|t| **t == Tier::Hot).count();
        let cold = self.resident.len() - hot;
        if hot != self.hot_used || cold != self.cold_used {
            return Err("used counters out of sync with residency".into());
        }
        for (k, _) in self.resident.iter() {
            if self.remaining_uses(*k) == 0 {
                return Err(format!("dead block {k} still resident"));
            }
        }
        if self.stats.hits() + self.stats.misses != self.stats.lookups {
            return Err("hit+miss != lookups".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache3() -> LivenessCache {
        // 4 slots: 2 hot + 2 cold; t_hot = 2
        let mut c = LivenessCache::new(4, 0.5, 2);
        c.init_uses([(1u64, 5u32), (2, 1), (3, 3), (4, 1), (5, 1)]);
        c
    }

    #[test]
    fn miss_then_admit_then_hit() {
        let mut c = cache3();
        assert_eq!(c.lookup(1), Access::Miss);
        assert_eq!(c.admit(1), Some(Tier::Hot)); // remaining 5 > t_hot 2
        assert_eq!(c.lookup(1), Access::Hit(Tier::Hot));
        c.check_invariants().unwrap();
    }

    #[test]
    fn low_reuse_goes_cold() {
        let mut c = cache3();
        assert_eq!(c.admit(2), Some(Tier::Cold)); // remaining 1 <= 2
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_on_nil_frees_slot() {
        let mut c = cache3();
        c.admit(2);
        assert!(c.is_resident(2));
        c.consume(2); // remaining 1 -> 0
        assert!(!c.is_resident(2));
        assert_eq!(c.stats().evictions_nil, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn bypass_when_tier_full_of_live_blocks() {
        let mut c = LivenessCache::new(2, 0.5, 0); // 1 hot + 1 cold, all >0 hot
        c.init_uses([(1u64, 9u32), (2, 9), (3, 9)]);
        assert_eq!(c.admit(1), Some(Tier::Hot));
        assert_eq!(c.admit(2), Some(Tier::Cold)); // hot full -> cold spill
        assert_eq!(c.admit(3), None); // both full, all live -> bypass
        assert_eq!(c.stats().bypasses, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn dead_block_not_admitted() {
        let mut c = cache3();
        assert_eq!(c.admit(99), None); // no uses registered
    }

    #[test]
    fn disabled_cache_never_hits() {
        let mut c = LivenessCache::disabled();
        c.init_uses([(1u64, 10u32)]);
        assert_eq!(c.lookup(1), Access::Miss);
        assert_eq!(c.admit(1), None);
        assert_eq!(c.lookup(1), Access::Miss);
        assert_eq!(c.stats().hit_rate(), 0.0);
    }

    #[test]
    fn has_space_for_respects_liveness_and_capacity() {
        let mut c = LivenessCache::new(2, 0.5, 0);
        c.init_uses([(1u64, 2u32), (2, 2), (3, 2)]);
        assert!(c.has_space_for(1));
        c.admit(1);
        assert!(!c.has_space_for(1)); // resident
        c.admit(2);
        assert!(!c.has_space_for(3)); // full of live blocks
        c.consume(1);
        c.consume(1); // evict-on-nil
        assert!(c.has_space_for(3));
    }

    #[test]
    fn hit_rate_accounting() {
        let mut c = cache3();
        c.lookup(1);
        c.admit(1);
        c.lookup(1);
        c.lookup(1);
        assert_eq!(c.stats().lookups, 3);
        assert!((c.stats().hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn consume_decrements_until_dead() {
        let mut c = cache3();
        c.admit(3);
        c.consume(3);
        c.consume(3);
        assert!(c.is_resident(3));
        assert_eq!(c.remaining_uses(3), 1);
        c.consume(3);
        assert!(!c.is_resident(3));
    }

    #[test]
    fn seed_resident_prices_as_hit_without_admission_stats() {
        let mut c = cache3();
        assert!(c.seed_resident(1)); // remaining 5 > t_hot 2 => hot
        assert_eq!(c.stats(), CacheStats::default(), "seeding must not count stats");
        assert_eq!(c.lookup(1), Access::Hit(Tier::Hot));
        c.check_invariants().unwrap();
        assert!(c.seed_resident(1), "re-seeding a resident key is a no-op success");
        assert!(!c.seed_resident(99), "dead keys are never seeded");
    }

    #[test]
    fn seed_resident_respects_capacity_and_spills() {
        let mut c = LivenessCache::new(2, 0.5, 0); // 1 hot + 1 cold, all >0 hot
        c.init_uses([(1u64, 9u32), (2, 9), (3, 9)]);
        assert!(c.seed_resident(1)); // hot
        assert!(c.seed_resident(2)); // hot full -> cold spill
        assert!(!c.seed_resident(3), "both tiers full of live blocks");
        assert_eq!(c.stats(), CacheStats::default());
        c.check_invariants().unwrap();
        // the skipped seed later prices as an ordinary miss
        assert_eq!(c.lookup(3), Access::Miss);
        // disabled cache never seeds (cacheless ablation stays cacheless)
        let mut d = LivenessCache::disabled();
        d.init_uses([(1u64, 10u32)]);
        assert!(!d.seed_resident(1));
    }

    #[test]
    fn consume_unregistered_key_is_a_noop() {
        // regression: consuming a key with no registered uses used to
        // insert a permanent zero entry into `remaining`, growing a
        // long-lived cache unboundedly
        let mut c = cache3();
        let before = c.tracked_keys();
        for k in 1000..1064u64 {
            c.consume(k);
        }
        assert_eq!(c.tracked_keys(), before, "phantom entries inserted");
        assert_eq!(c.stats(), CacheStats::default(), "no-op must not touch stats");
        c.check_invariants().unwrap();
    }

    #[test]
    fn dead_counters_are_dropped_not_parked_at_zero() {
        let mut c = cache3();
        let before = c.tracked_keys();
        c.consume(2); // key 2 registered with 1 use -> dead, entry dropped
        assert_eq!(c.tracked_keys(), before - 1);
        assert_eq!(c.remaining_uses(2), 0);
        c.consume(2); // now unregistered: still a no-op
        assert_eq!(c.tracked_keys(), before - 1);
    }
}
