//! FAST-Prefill — full-system reproduction of "FAST-Prefill: FPGA
//! Accelerated Sparse Attention for Long Context LLM Prefill".
//!
//! Three-layer architecture (see DESIGN.md):
//!  * L3 (this crate): coordinator, FlexPrefill algorithm, liveness-driven
//!    KV cache, cycle-approximate U280 simulator, A5000 cost model.
//!  * L2/L1 (python/compile): JAX chunk graphs + Pallas kernels, AOT-lowered
//!    to HLO text, executed through [`runtime`] on the PJRT CPU client.
//!
//! Public API tour:
//!  * [`coordinator::Engine`] — end-to-end chunked prefill, over the AOT
//!    artifacts (`pjrt` feature) or artifact-free on the native kernels;
//!    also exposed as resumable per-layer phases ([`coordinator::Phase`]).
//!  * [`coordinator::Server`] — phase-pipelined multi-request serving on
//!    one shared thread budget ([`util::pool::PoolBudget`]).
//!  * [`tensor::tile`] + [`tensor::simd`] + [`util::pool`] — the
//!    block-major kernel layer: cache-blocked W8A8/f32 kernels with
//!    runtime-dispatched SIMD inner loops (AVX2/NEON, `FASTP_KERNEL`
//!    override) and the shared worker pool (`FASTP_THREADS`); results
//!    are bit-identical for any thread count and kernel backend.
//!  * [`flexprefill`] — Algorithm 1 (dynamic sparse index generation).
//!  * [`sim`] — FPGA performance/energy model (Figures 5-8, Tables I/II).
//!  * [`gpu_model`] — the A5000 baseline cost model.
//!  * [`accuracy`] — Table III retrieval-accuracy proxy.

pub mod accuracy;
pub mod config;
pub mod coordinator;
pub mod flexprefill;
pub mod gpu_model;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod workload;
